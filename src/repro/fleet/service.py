"""Fleet aggregation service: ingest -> registry -> top-K profiler routing.

The serving loop of the always-on signal at fleet scale:

  1. `submit()` decodes one wire packet (failure-safe) and folds it into
     the job's streaming frontier state — incremental, no batch re-run;
  2. `refresh_batched()` stacks the jobs that shipped raw windows into one
     [J, N, R, S] tensor per shape group and runs the fused fleet kernel
     (jobs on the grid dimension): fleet-wide shares/gains/leaders in one
     pass instead of J dispatches;
  3. `route(k)` answers the operator question two steps past the paper —
     not just *where do I aim the heavy profiler* but *what is a fix
     worth, and is the fault still happening*: the top-K non-degraded
     jobs by estimated recoverable seconds (counterfactual what-if
     evidence) weighted by each candidate's temporal persistence
     (`core.regimes` — persistent > recurring > healed transient), each
     with the (stage, rank) candidate that yields that recovery and its
     regime classification.

Ticks are logical: callers advance `tick()` per aggregation round; jobs
silent for `evict_after` ticks are evicted (bounded state, dead jobs never
pin memory).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import TYPE_CHECKING, Iterable

import numpy as np

from ..core.streaming import WindowStager
from ..obs import FleetObs
from ..telemetry.packets import EvidencePacket
from .ingest import FleetIngest
from .registry import FleetRegistry, JobState

if TYPE_CHECKING:  # pragma: no cover
    from ..incidents import IncidentEngine, Topology

__all__ = ["FleetService", "RouteEntry"]


@dataclasses.dataclass(frozen=True)
class RouteEntry:
    """One 'aim the profiler here' answer.

    `score` is the estimated recoverable seconds weighted by the fault's
    temporal persistence: routing ranks jobs by what a fix is worth *and
    whether the fault is still happening*.  `recoverable_s` keeps the raw
    counterfactual seconds; `persistence` is the [0, 1] regime weight
    (1.0 when the job has no temporal evidence — unknown is never
    deprioritized), `regime` the temporal class of the routed candidate
    ("" when unknown) and `onset_step` its job-global onset.  `urgency`
    carries the old evidence-weighted anomaly score for dashboards.
    """

    job_id: str
    stage: str
    rank: int
    score: float
    window_index: int
    labels: tuple[str, ...]
    recoverable_s: float = 0.0
    urgency: float = 0.0
    regime: str = ""
    persistence: float = 1.0
    onset_step: int = -1


class FleetService:
    #: routing-score floor of the persistence weight: a fully healed
    #: fault keeps this fraction of its recoverable-seconds score, so it
    #: ranks far below live faults but never silently vanishes from the
    #: answer (the operator can still see what it was worth).
    PERSISTENCE_FLOOR = 0.05

    def __init__(
        self,
        *,
        window_capacity: int = 100,
        evict_after: int = 10,
        degrade_after: int = 3,
        max_jobs: int = 100_000,
        regime_windows: int = 4,
        incidents: "IncidentEngine | None" = None,
        fused: bool = True,
        topology: "Topology | None" = None,
        device=None,
        obs: bool = True,
        obs_name: str = "service",
    ):
        self.ingest = FleetIngest()
        self.registry = FleetRegistry(
            window_capacity=window_capacity,
            evict_after=evict_after,
            degrade_after=degrade_after,
            max_jobs=max_jobs,
            regime_windows=regime_windows,
        )
        #: True routes `refresh_batched` through the fused megakernel
        #: (`fused_fleet_tick`: one dispatch, one HBM read of the stacked
        #: windows); False keeps the four-dispatch reference composition.
        #: Flip to False when triaging a suspected kernel miscompile —
        #: the two paths are bit-identical by contract, so any divergence
        #: between them IS the bug report.
        self.fused = bool(fused)
        self._stager = WindowStager()
        #: optional incident tier (`repro.incidents.IncidentEngine`):
        #: when attached, every `tick()` feeds it this round's route
        #: entries, evictions, and per-job activity series, and packets'
        #: declared host placements flow into its `Topology` — route
        #: answers gain identity, lifecycle, and common-cause grouping.
        self.incidents = incidents
        #: optional coordinator-owned `incidents.Topology` to declare
        #: packet host placements into when this service runs as ONE
        #: SHARD of a `ShardedFleetService`: shards carry no engine of
        #: their own (the coordinator owns the single fleet-wide one),
        #: but their packets' placements must still reach it.  Ignored
        #: when `incidents` is attached (the engine's topology wins).
        self._topology = topology
        #: optional jax device pinning the batched kernel refresh: a
        #: sharded coordinator places each shard's refresh on its own
        #: forced-host CPU device (`launch.mesh.make_fleet_mesh`), so N
        #: shards dispatch onto N devices.  None = jax's default device.
        self.device = device
        #: always-on self-observability (`repro.obs`): the tick pipeline
        #: timed as an ordered stage vector (decode -> stage -> kernel ->
        #: epilog -> regimes -> correlate -> route), counters/histograms,
        #: and a flight-recorder ring — surfaced as `snapshot()["obs"]`.
        #: `obs=False` exists only for the overhead benchmark's control
        #: arm and for parity triage; route()/snapshot() outputs are
        #: bit-identical either way (the "obs" section aside).
        self.obs = FleetObs(name=obs_name) if obs else None
        self._tick = 0
        self.evicted_total = 0

    def _phase(self, name: str):
        """Tick-phase span (no-op context when obs is disabled)."""
        if self.obs is None:
            return contextlib.nullcontext()
        return self.obs.phase(name)

    # -- ingest ------------------------------------------------------------

    @property
    def current_tick(self) -> int:
        return self._tick

    def submit(
        self, job_id: str, data: bytes | EvidencePacket
    ) -> JobState | None:
        """Ingest one packet for `job_id`; returns the job state, or None
        if the payload was undecodable (counted, never raised)."""
        with self._phase("tick.decode"):
            pkt = self.ingest.decode(data)
        if self.obs is not None:
            self.obs.metrics.counter("packets").inc()
        if pkt is None:
            if self.obs is not None:
                self.obs.metrics.counter("decode_errors").inc()
            return None
        with self._phase("tick.regimes"):
            job = self.registry.update(job_id, pkt, self._tick)
        if job is not None:
            if self.obs is not None:
                self.obs.metrics.counter("packets_accepted").inc()
            self._declare_hosts(job_id, pkt)
        return job

    def _declare_hosts(self, job_id: str, pkt: EvidencePacket) -> None:
        """Land a packet's declared placement in the fleet topology —
        the attached engine's, or the coordinator sink when this service
        is one shard of a sharded fleet.  SFP2-v3 packets also carry the
        fabric tiers (per-rank switch/pod ids); v2's host-only placement
        declares just the host tier, never erasing a prior fabric claim."""
        if not pkt.hosts:
            return
        if self.incidents is not None:
            self.incidents.topology.declare(
                job_id, pkt.hosts, switches=pkt.switches, pods=pkt.pods
            )
        elif self._topology is not None:
            self._topology.declare(
                job_id, pkt.hosts, switches=pkt.switches, pods=pkt.pods
            )

    def submit_many(
        self,
        items: Iterable[tuple[str, bytes | EvidencePacket]],
        *,
        refresh: bool = False,
    ) -> int:
        """Ingest one tick's batch of `(job_id, wire)` pairs; returns how
        many were accepted (decoded AND folded — a full registry refusing
        a new job id does not count).

        This is the amortized tick path: the whole batch decodes through
        `FleetIngest.decode_many` before any registry fold, and with
        `refresh=True` the accepted raw windows go straight into one
        `refresh_batched()` kernel pass — wire bytes to fleet-wide
        shares/what-if matrices with no intermediate window copies
        (SFP2 float64 payloads stay zero-copy views until the registry's
        single float32 cast).
        """
        pairs = list(items)
        with self._phase("tick.decode"):
            pkts = self.ingest.decode_many(data for _, data in pairs)
        accepted = 0
        with self._phase("tick.regimes"):
            for (job_id, _), pkt in zip(pairs, pkts):
                if pkt is None:
                    continue
                if self.registry.update(job_id, pkt, self._tick) is not None:
                    accepted += 1
                    self._declare_hosts(job_id, pkt)
        if self.obs is not None:
            m = self.obs.metrics
            m.counter("packets").inc(len(pairs))
            m.counter("packets_accepted").inc(accepted)
            m.counter("decode_errors").inc(
                sum(1 for p in pkts if p is None)
            )
        if refresh:
            self.refresh_batched()
        return accepted

    def tick(self) -> list[str]:
        """Advance the logical clock; evicts and returns stale job ids.

        With an incident engine attached, the tick also folds this
        round's full route answer (every routable job), the evictions,
        and the per-job regime activity series into the engine — the
        stateless per-window answer becomes durable incidents.
        """
        self._tick += 1
        with self._phase("tick.regimes"):
            evicted = self.registry.evict_stale(self._tick)
            self.evicted_total += len(evicted)
            activity = None
            if self.incidents is not None:
                activity = {
                    job.job_id: (job.regimes.activity(), job.stages)
                    for job in self.registry.jobs()
                    if job.regimes is not None and job.regimes.num_steps
                }
        if self.incidents is not None:
            routes = self.route(len(self.registry))
            with self._phase("tick.correlate"):
                self.incidents.observe(
                    self._tick,
                    routes,
                    evicted=evicted,
                    activity=activity,
                )
        if self.obs is not None:
            self.obs.on_tick(
                self._tick,
                evicted=len(evicted),
                live=len(self.registry),
            )
        return evicted

    # -- batched kernel refresh --------------------------------------------

    def refresh_batched(
        self, *, min_jobs: int = 1, fused: bool | None = None
    ) -> int:
        """Re-account every *dirty* window-carrying job through the fleet
        tick kernel, grouped by window shape.  Returns jobs refreshed.

        Dirty = a new raw window arrived since the last refresh (the
        registry nulls `kernel_shares` on ingest), so per-tick cost scales
        with updated jobs, not fleet size.  Every dirty group refreshes by
        default — routing quality depends on the what-if matrix, and a
        skipped group would also keep its raw windows pinned; callers that
        prefer leaving tiny groups to their streaming state can raise
        `min_jobs`.

        Each refresh runs the frontier accounting AND the batched
        counterfactual route on the same stacked tensor, so every
        refreshed job carries a dense [S, R] recoverable-time matrix —
        the evidence `route(k)` ranks by.  With `fused` (default: the
        service flag) both come out of ONE `fused_fleet_tick` dispatch
        that reads the window tensor from HBM once; `fused=False` keeps
        the four-dispatch reference composition (`four_dispatch_tick`),
        bit-identical by contract.  The counterfactual replays each job's
        *declared* sync profile (packet `sync_stages`), so jobs are
        grouped by (window shape, sync profile) — the sync segmentation
        is a static kernel argument and must match within a batch.
        """
        from ..kernels.frontier import four_dispatch_tick, fused_fleet_tick

        use_fused = self.fused if fused is None else bool(fused)
        refreshed = 0
        for (shape, sync_idx), jobs in sorted(
            self.registry.dirty_groups().items()
        ):
            if len(jobs) < min_jobs:
                continue
            # Stage into the recycled host buffer: the job dimension is
            # padded to the next power of two (replicating the last job's
            # window) so elastic fleets — where the live job count J
            # drifts every tick — hit a bounded set of compiled kernel
            # shapes instead of one ~seconds-long jit compile per
            # distinct J.  Per-job accounting is independent along the
            # grid dimension, so the first-J outputs are unchanged; the
            # padded rows are sliced away below.
            j_live = len(jobs)
            with self._phase("tick.stage"):
                stacked = self._stager.stage([j.last_window for j in jobs])
                if self.device is not None:
                    # shard-pinned refresh: commit the staged tensor to
                    # this service's device so the dispatch runs there
                    # (same compiled program on every CPU device —
                    # bit-identical outputs, tests/test_sharded_fleet.py).
                    import jax

                    stacked = jax.device_put(stacked, self.device)
            with self._phase("tick.kernel"):
                if use_fused:
                    # one dispatch, one HBM read; the device input buffer
                    # is donated — consumed by the kernel, never copied
                    # back.
                    tick = fused_fleet_tick(
                        stacked, sync_stages=sync_idx,
                        with_regimes=False, donate=True,
                    )
                else:
                    tick = four_dispatch_tick(
                        stacked, sync_stages=sync_idx, with_regimes=False,
                    )
            with self._phase("tick.epilog"):
                pkt, wif = tick.frontier, tick.whatif
                shares = np.asarray(pkt.shares)[:j_live]   # [J, S]
                gains = np.asarray(pkt.gains)[:j_live]     # [J, S]
                leader = np.asarray(pkt.leader)[:j_live]   # [J, N, S]
                whatif = np.asarray(wif.matrix)[:j_live]   # [J, S, R]
                for i, job in enumerate(jobs):
                    job.kernel_shares = shares[i]
                    job.kernel_gains = gains[i]
                    top = int(np.argmax(shares[i]))
                    # mode of the per-step leader at the top boundary
                    ranks, counts = np.unique(
                        leader[i, :, top], return_counts=True
                    )
                    job.kernel_leader = int(ranks[np.argmax(counts)])
                    job.whatif = whatif[i]
                    # raw window consumed: release it (bounded registry)
                    job.last_window = None
                    refreshed += 1
        if self.obs is not None and refreshed:
            self.obs.metrics.counter("jobs_refreshed").inc(refreshed)
        return refreshed

    # -- routing -----------------------------------------------------------

    def route(self, k: int = 10) -> list[RouteEntry]:
        """Top-K jobs by persistence-weighted recoverable seconds.

        The ranking answers "where is a fix worth the most step time —
        and is the fault still happening": each job's raw score is its
        best counterfactual (the argmax cell of the kernel-refreshed
        what-if matrix when fresh, else the packet's whole-stage clipped
        gain converted to seconds — see `JobState.recoverable`),
        multiplied by the candidate's temporal persistence weight
        (`core.regimes`): a persistent fault keeps ~its full price, an
        intermittent its duty cycle, a healed blip decays toward the
        `PERSISTENCE_FLOOR`.  Jobs with no temporal evidence (compact
        packets) keep weight 1.0 — unknown is never deprioritized.  The
        reported (stage, rank) is that same candidate — one evidence
        source per answer, never a stage from one window paired with
        another's rank.

        Ordering is fully deterministic: weighted seconds descending,
        ties broken by job id ascending, then by rank index ascending
        (stable across dict insertion order and refresh timing; the
        third key guards the day an answer carries several rank
        candidates per job — two entries tying on (score, job_id) must
        still order identically on every run).  Degraded
        (telemetry_limited) jobs never appear: quality labels must not
        trigger workload-touching actions.
        """
        with self._phase("tick.route"):
            floor = self.PERSISTENCE_FLOOR
            scored = []
            for job in self.registry.jobs():
                rec, si, ri = job.recoverable()
                if rec <= 0.0:
                    continue
                w = job.persistence(si, ri)
                call = job.regime_call(si, ri)
                score = (
                    rec if w is None
                    else rec * (floor + (1.0 - floor) * w)
                )
                scored.append((score, rec, si, ri, w, call, job))
            scored.sort(key=lambda t: (-t[0], t[6].job_id, t[3]))
            out: list[RouteEntry] = []
            for score, rec, si, ri, w, call, job in scored[: max(0, k)]:
                pkt = job.last_packet
                stage = job.stages[si] if 0 <= si < len(job.stages) else ""
                out.append(
                    RouteEntry(
                        job_id=job.job_id,
                        stage=stage,
                        rank=ri,
                        score=score,
                        window_index=pkt.window_index if pkt else -1,
                        labels=job.labels,
                        recoverable_s=rec,
                        urgency=job.urgency(),
                        regime=call.name if call is not None else "",
                        persistence=1.0 if w is None else w,
                        onset_step=call.onset if call is not None else -1,
                    )
                )
        if self.obs is not None:
            self.obs.on_route(self._tick, out)
        return out

    # -- summaries ---------------------------------------------------------

    def snapshot(self) -> dict:
        jobs = self.registry.jobs()
        regimes: dict[str, int] = {}
        for j in jobs:
            for name, c in j.regime_counts().items():
                if name != "none":
                    regimes[name] = regimes.get(name, 0) + c
        out = {
            "tick": self._tick,
            "jobs": len(jobs),
            "degraded_jobs": sum(1 for j in jobs if j.degraded),
            # live fault candidates per temporal class, fleet-wide
            "regimes": regimes,
            "evicted_total": self.evicted_total,
            "rejected_total": self.registry.rejected_total,
            "duplicate_total": self.registry.duplicate_total,
            "packets": self.ingest.stats.packets,
            "bytes": self.ingest.stats.bytes,
            "decode_errors": self.ingest.stats.decode_errors,
            "predecoded": self.ingest.stats.predecoded,
            "avg_wire_bytes": self.ingest.stats.avg_wire_bytes,
            # lifetime counter (registry-owned): monotonic even across
            # eviction — summing live jobs made this run backwards.
            "windows_seen": self.registry.windows_total,
        }
        if self.incidents is not None:
            # live incidents per lifecycle state (+ lifetime resolved)
            out["incidents"] = self.incidents.counts()
            # conflicting-claim re-homings (last-writer-wins topology
            # churn) — operators watch this to catch placement drift.
            out["rehomed"] = self.incidents.topology.rehomed
        if self.obs is not None:
            # self-observability section (docs/observability.md) — the
            # only snapshot key carrying wall-clock state; parity
            # comparisons strip it (obs-on == obs-off elsewhere, gated
            # by benchmarks/obs_overhead.py).
            out["obs"] = self.obs.section()
        return out
