"""Per-job registry: bounded streaming state for every job in the fleet.

Each registered job owns a `StreamingFrontier` (O(window * S) state — the
[N, R, S] window matrices are folded step-by-step and dropped, never
accumulated), the last decoded packet summary, and liveness counters that
mirror the failure-safe gather semantics of `repro.telemetry.gather`:

  * a job whose packets report ``gather_ok=False`` accumulates a missing
    streak; past ``degrade_after`` consecutive windows the job is marked
    degraded and its absent ranks are recorded as dead (the fleet analogue
    of the fail-slow -> fail-stop promotion in `distributed.policy`);
  * a job that stops reporting entirely for ``evict_after`` ticks is
    evicted — symmetric failure-safe collection, bounded registry.

Degraded jobs stay visible (operators need to see them) but are excluded
from profiler routing: telemetry-quality labels never trigger
workload-touching actions.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.streaming import StreamingFrontier
from ..telemetry.packets import EvidencePacket

__all__ = ["JobState", "FleetRegistry"]

_STRONG_LABELS = frozenset(
    {"direct_exposure", "sync_wait_dependent", "likely_sync_wait"}
)


@dataclasses.dataclass
class JobState:
    """Mutable per-job record held by the registry."""

    job_id: str
    stages: tuple[str, ...]
    world_size: int
    schema_hash: str
    streaming: StreamingFrontier
    #: last full [N, R, S] window (f32, only when packets ship windows);
    #: feeds the batched fleet-kernel refresh, which releases it — raw
    #: windows are consumed, never accumulated.
    last_window: np.ndarray | None = None
    last_packet: EvidencePacket | None = None
    last_tick: int = 0
    windows_seen: int = 0
    missing_streak: int = 0
    dead_ranks: frozenset[int] = frozenset()
    degraded: bool = False
    #: kernel-refreshed per-stage shares/gains ([S] each, None until a
    #: batched refresh has covered this job).
    kernel_shares: np.ndarray | None = None
    kernel_gains: np.ndarray | None = None
    kernel_leader: int = -1

    @property
    def labels(self) -> tuple[str, ...]:
        return self.last_packet.labels if self.last_packet else ()

    @property
    def has_strong_evidence(self) -> bool:
        return bool(_STRONG_LABELS & set(self.labels))

    def shares(self) -> np.ndarray:
        """Freshest per-stage shares: kernel > streaming > packet header."""
        if self.kernel_shares is not None:
            return self.kernel_shares
        if self.streaming.num_steps:
            return self.streaming.shares()
        if self.last_packet is not None:
            return np.asarray(self.last_packet.shares)
        return np.zeros(len(self.stages))

    def urgency(self) -> float:
        """Scalar 'how much does this job need a heavy profiler' score."""
        if self.degraded or self.last_packet is None:
            return 0.0
        sh = self.shares()
        top_share = float(sh.max()) if sh.size else 0.0
        top_gain = max(self.last_packet.gains, default=0.0)
        if self.kernel_gains is not None and self.kernel_gains.size:
            top_gain = max(top_gain, float(self.kernel_gains.max()))
        return (2.0 if self.has_strong_evidence else 0.0) + top_share + top_gain


class FleetRegistry:
    """Bounded job table with tick-based liveness."""

    def __init__(self, *, window_capacity: int = 100, evict_after: int = 10,
                 degrade_after: int = 3, max_jobs: int = 100_000):
        self.window_capacity = window_capacity
        self.evict_after = evict_after
        self.degrade_after = degrade_after
        self.max_jobs = max_jobs
        self.rejected_total = 0
        self.duplicate_total = 0
        self._jobs: dict[str, JobState] = {}

    # -- updates -----------------------------------------------------------

    def update(
        self, job_id: str, pkt: EvidencePacket, tick: int
    ) -> JobState | None:
        """Fold one decoded packet into the job's state (creates the job).

        Returns None when the registry is full and `job_id` is new: bounded
        state means refusing registrations, never silently deleting a live
        job.  Refusals are counted in `rejected_total`.
        """
        job = self._jobs.get(job_id)
        if job is None or job.schema_hash != pkt.schema_hash:
            if job is None and len(self._jobs) >= self.max_jobs:
                self.rejected_total += 1
                return None
            # new job, or schema break: restart the stream (Table 11 rule —
            # never merge rows across schema hashes).
            job = JobState(
                job_id=job_id,
                stages=tuple(pkt.stages),
                world_size=pkt.world_size,
                schema_hash=pkt.schema_hash,
                streaming=StreamingFrontier(
                    pkt.world_size, len(pkt.stages),
                    capacity=self.window_capacity,
                ),
            )
            self._jobs[job_id] = job
        elif (
            job.last_packet is not None
            and pkt.window_index == job.last_packet.window_index
        ):
            # transport retry re-delivered a window already folded: refresh
            # liveness only, never double-count the window.
            self.duplicate_total += 1
            job.last_tick = tick
            return job
        job.last_tick = tick
        job.windows_seen += 1
        job.last_packet = pkt

        if pkt.gather_ok:
            job.missing_streak = 0
            job.degraded = False
            job.dead_ranks = frozenset()   # a healthy gather clears the set
        else:
            job.missing_streak += 1
            if job.missing_streak >= self.degrade_after:
                job.degraded = True
                if pkt.present_ranks:
                    job.dead_ranks = frozenset(
                        set(range(pkt.world_size)) - set(pkt.present_ranks)
                    )

        if pkt.window is not None:
            w = np.asarray(pkt.window, np.float64)
            if w.ndim == 3 and w.shape[1:] == (pkt.world_size, len(pkt.stages)):
                job.streaming.push_many(w)
                # f32 is what the kernel consumes; half the pinned bytes,
                # and refresh_batched() releases it after the refresh.
                job.last_window = w.astype(np.float32)
                # a fresh raw window invalidates the last kernel refresh
                job.kernel_shares = None
                job.kernel_gains = None
                job.kernel_leader = -1
        return job

    def evict_stale(self, tick: int) -> list[str]:
        """Drop jobs silent for >= evict_after ticks; returns evicted ids."""
        stale = [
            jid for jid, j in self._jobs.items()
            if tick - j.last_tick >= self.evict_after
        ]
        for jid in stale:
            del self._jobs[jid]
        return stale

    # -- reads -------------------------------------------------------------

    def get(self, job_id: str) -> JobState | None:
        return self._jobs.get(job_id)

    def jobs(self) -> list[JobState]:
        return list(self._jobs.values())

    def __len__(self) -> int:
        return len(self._jobs)

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs
