"""Per-job registry: bounded streaming state for every job in the fleet.

Each registered job owns a `StreamingFrontier` (O(window * S) state — the
[N, R, S] window matrices are folded step-by-step and dropped, never
accumulated), the last decoded packet summary, and liveness counters that
mirror the failure-safe gather semantics of `repro.telemetry.gather`:

  * a job whose packets report ``gather_ok=False`` accumulates a missing
    streak; past ``degrade_after`` consecutive windows the job is marked
    degraded and its absent ranks are recorded as dead (the fleet analogue
    of the fail-slow -> fail-stop promotion in `distributed.policy`);
  * a job that stops reporting entirely for ``evict_after`` ticks is
    evicted — symmetric failure-safe collection, bounded registry.

Degraded jobs stay visible (operators need to see them) but are excluded
from profiler routing: telemetry-quality labels never trigger
workload-touching actions.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.regimes import REGIME_NAMES, RegimeCall
from ..core.streaming import StreamingFrontier, StreamingRegimes
from ..core.whatif import make_sync_mask
from ..telemetry.packets import EvidencePacket

__all__ = ["JobState", "FleetRegistry"]

_STRONG_LABELS = frozenset(
    {"direct_exposure", "sync_wait_dependent", "likely_sync_wait"}
)


@dataclasses.dataclass
class JobState:
    """Mutable per-job record held by the registry."""

    job_id: str
    stages: tuple[str, ...]
    world_size: int
    schema_hash: str
    streaming: StreamingFrontier
    #: declared sync profile (stage names ending in a group barrier) — set
    #: from the job's packets; drives the counterfactual replay model.
    sync_stages: tuple[str, ...] = ()
    #: declared per-rank host placement (SFP2-v2 host section); feeds the
    #: incident tier's `Topology`.  () = the job never declared one.
    hosts: tuple[str, ...] = ()
    #: last full [N, R, S] window (f32, only when packets ship windows);
    #: feeds the batched fleet-kernel refresh, which releases it — raw
    #: windows are consumed, never accumulated.
    last_window: np.ndarray | None = None
    last_packet: EvidencePacket | None = None
    last_tick: int = 0
    windows_seen: int = 0
    missing_streak: int = 0
    dead_ranks: frozenset[int] = frozenset()
    degraded: bool = False
    #: kernel-refreshed per-stage shares/gains ([S] each, None until a
    #: batched refresh has covered this job).
    kernel_shares: np.ndarray | None = None
    kernel_gains: np.ndarray | None = None
    kernel_leader: int = -1
    #: kernel-refreshed counterfactual what-if matrix W[S, R] (recoverable
    #: seconds per (stage, rank) candidate); None until a batched refresh
    #: has covered this job.
    whatif: np.ndarray | None = None
    #: incremental temporal regime engine over the job's pushed windows —
    #: spans multiple evidence packets (the temporal question needs a
    #: history longer than one window).  None until the first raw window
    #: arrives; the reference is fixed from that window's cohort median
    #: (a moving reference would make early/late folds disagree).
    regimes: StreamingRegimes | None = None
    #: job-global step index of the regime stream's first pushed step
    #: (from the first packet's declared `first_step`; 0 when packets
    #: predate the field) — converts window-relative onsets to job steps.
    step_origin: int = 0
    #: sync profile the regime stream was built with; a later packet
    #: declaring a different profile rebuilds the stream (the imputation
    #: semantics of its excess rows changed, old history not comparable).
    regime_sync: tuple[str, ...] = ()
    #: cached `RegimeResult` of `regimes` (invalidated on every ingest).
    _regime_cache: object = None

    @property
    def labels(self) -> tuple[str, ...]:
        return self.last_packet.labels if self.last_packet else ()

    def sync_index_tuple(self) -> tuple[int, ...]:
        """Declared sync stages as ordered stage indices (kernel static
        arg and batched-refresh group key; unknown names are ignored)."""
        return tuple(
            i for i, s in enumerate(self.stages) if s in set(self.sync_stages)
        )

    @property
    def has_strong_evidence(self) -> bool:
        return bool(_STRONG_LABELS & set(self.labels))

    def shares(self) -> np.ndarray:
        """Freshest per-stage shares: kernel > streaming > packet header."""
        if self.kernel_shares is not None:
            return self.kernel_shares
        if self.streaming.num_steps:
            return self.streaming.shares()
        if self.last_packet is not None:
            return np.asarray(self.last_packet.shares)
        return np.zeros(len(self.stages))

    def urgency(self) -> float:
        """Scalar 'how much does this job need a heavy profiler' score."""
        if self.degraded or self.last_packet is None:
            return 0.0
        sh = self.shares()
        top_share = float(sh.max()) if sh.size else 0.0
        top_gain = max(self.last_packet.gains, default=0.0)
        if self.kernel_gains is not None and self.kernel_gains.size:
            top_gain = max(top_gain, float(self.kernel_gains.max()))
        return (2.0 if self.has_strong_evidence else 0.0) + top_share + top_gain

    def recoverable(self) -> tuple[float, int, int]:
        """Estimated recoverable seconds and the candidate that yields them.

        Returns ``(seconds, stage_index, rank)``.  Evidence ladder,
        freshest first (one source per answer — never a stage from one
        window paired with another window's rank):

          1. kernel what-if matrix: the exact counterfactual, argmax cell;
          2. packet gains x a window denominator: the whole-stage clipped
             gain converted to seconds (a stage-level estimate).  The rank
             is the packet's own leader *only when* the gain-argmax stage
             is also the packet's top routing stage — the leader belongs
             to the packet's routing answer, and pairing it with some
             other stage would violate the one-source rule; otherwise the
             rank is reported unknown (-1).  The denominator is the
             packet's own `exposed_total` when declared, else the
             streaming state's summed exposed makespan (packets from
             pre-whatif emitters decode with exposed_total = -1);
          3. gains with no denominator anywhere (compact pre-whatif
             packets): the top gain *fraction* stands in as the score —
             dimensionless, so such jobs rank conservatively against
             seconds-priced peers, but they stay routable;
          4. nothing usable: (0.0, -1, -1).

        Degraded jobs report 0.0 — quality labels never route profilers.
        """
        if self.degraded:
            return 0.0, -1, -1
        if self.whatif is not None and self.whatif.size:
            flat = int(np.argmax(self.whatif))
            si, ri = divmod(flat, self.whatif.shape[1])
            return float(self.whatif[si, ri]), si, ri
        pkt = self.last_packet
        if pkt is not None and pkt.gains:
            si = int(np.argmax(pkt.gains))
            denom = pkt.exposed_total
            if denom <= 0.0 and self.streaming.num_steps:
                denom = self.streaming.exposed_total()
            scale = denom if denom > 0.0 else 1.0
            rec = float(pkt.gains[si]) * scale
            stage_name = self.stages[si] if si < len(self.stages) else ""
            ri = (
                pkt.leader_rank
                if pkt.routing_stages and pkt.routing_stages[0] == stage_name
                else -1
            )
            if rec > 0.0:
                return rec, si, ri
        return 0.0, -1, -1

    # -- temporal regime state --------------------------------------------

    def regime_result(self):
        """Window `RegimeResult` of the job's regime stream, cached until
        the next ingest; None when no window has ever been pushed (or the
        stream is empty)."""
        if self.regimes is None or not self.regimes.num_steps:
            return None
        if self._regime_cache is None:
            self._regime_cache = self.regimes.result()
        return self._regime_cache

    def regime_call(self, stage: int, rank: int) -> RegimeCall | None:
        """Temporal classification of one candidate, with the onset
        converted to job-global step coordinates.  None when the job has
        no regime evidence (compact packets, empty stream, or a candidate
        outside the matrix)."""
        res = self.regime_result()
        if res is None:
            return None
        if not (
            0 <= stage < res.labels.shape[0] and 0 <= rank < res.labels.shape[1]
        ):
            return None
        call = res.call(stage, rank)
        if call.onset >= 0:
            # ring-relative -> stream-relative -> job-global steps
            dropped = self.regimes.steps_seen - self.regimes.num_steps
            call = dataclasses.replace(
                call, onset=self.step_origin + dropped + call.onset
            )
        return call

    def persistence(self, stage: int, rank: int) -> float | None:
        """Persistence weight of one candidate in [0, 1]; None when the
        job has no regime evidence (callers treat unknown as 1.0 — a
        fault of unknown temporal state must not be deprioritized)."""
        res = self.regime_result()
        if res is None:
            return None
        if not (
            0 <= stage < res.weights.shape[0] and 0 <= rank < res.weights.shape[1]
        ):
            return None
        return float(res.weights[stage, rank])

    def regime_counts(self) -> dict[str, int]:
        """Live candidates per temporal class (all-`none` when unknown)."""
        res = self.regime_result()
        if res is None:
            return {name: 0 for name in REGIME_NAMES}
        return res.counts()


class FleetRegistry:
    """Bounded job table with tick-based liveness."""

    def __init__(self, *, window_capacity: int = 100, evict_after: int = 10,
                 degrade_after: int = 3, max_jobs: int = 100_000,
                 regime_windows: int = 4):
        self.window_capacity = window_capacity
        self.evict_after = evict_after
        self.degrade_after = degrade_after
        self.max_jobs = max_jobs
        #: regime-stream depth in window_capacity multiples: the temporal
        #: question needs a history longer than one window, so each job's
        #: StreamingRegimes retains `regime_windows * window_capacity`
        #: steps (bounded — the excess ring is O(capacity * R * S)).
        self.regime_windows = max(1, regime_windows)
        self.rejected_total = 0
        self.duplicate_total = 0
        #: windows accepted over the registry's lifetime.  Monotonic by
        #: construction — eviction and schema restarts never decrement it
        #: (per-job `windows_seen` resets with the job; summing it across
        #: live jobs made the fleet counter run *backwards* whenever a
        #: job was evicted).
        self.windows_total = 0
        self._jobs: dict[str, JobState] = {}

    # -- updates -----------------------------------------------------------

    def update(
        self, job_id: str, pkt: EvidencePacket, tick: int
    ) -> JobState | None:
        """Fold one decoded packet into the job's state (creates the job).

        Returns None when the registry is full and `job_id` is new: bounded
        state means refusing registrations, never silently deleting a live
        job.  Refusals are counted in `rejected_total`.
        """
        job = self._jobs.get(job_id)
        if job is None or job.schema_hash != pkt.schema_hash:
            if job is None and len(self._jobs) >= self.max_jobs:
                self.rejected_total += 1
                return None
            # new job, or schema break: restart the stream (Table 11 rule —
            # never merge rows across schema hashes).
            job = JobState(
                job_id=job_id,
                stages=tuple(pkt.stages),
                world_size=pkt.world_size,
                schema_hash=pkt.schema_hash,
                streaming=StreamingFrontier(
                    pkt.world_size, len(pkt.stages),
                    capacity=self.window_capacity,
                ),
                sync_stages=tuple(pkt.sync_stages),
            )
            self._jobs[job_id] = job
        elif (
            job.last_packet is not None
            and pkt.window_index == job.last_packet.window_index
        ):
            # transport retry re-delivered a window already folded: refresh
            # liveness only, never double-count the window.
            self.duplicate_total += 1
            job.last_tick = tick
            return job
        job.last_tick = tick
        job.windows_seen += 1
        self.windows_total += 1
        job.last_packet = pkt
        if pkt.sync_stages:
            job.sync_stages = tuple(pkt.sync_stages)
        if pkt.hosts:
            job.hosts = tuple(pkt.hosts)
        # Any accepted packet is fresher evidence than a kernel refresh
        # computed from an older window: invalidate the refreshed state so
        # `recoverable()`/`shares()` fall to the packet (or the next
        # refresh) instead of serving a stale matrix forever.
        job.kernel_shares = None
        job.kernel_gains = None
        job.kernel_leader = -1
        job.whatif = None
        job._regime_cache = None

        if pkt.gather_ok:
            job.missing_streak = 0
            job.degraded = False
            job.dead_ranks = frozenset()   # a healthy gather clears the set
        else:
            job.missing_streak += 1
            if job.missing_streak >= self.degrade_after:
                job.degraded = True
                if pkt.present_ranks:
                    job.dead_ranks = frozenset(
                        set(range(pkt.world_size)) - set(pkt.present_ranks)
                    )

        if pkt.window is not None:
            w = np.asarray(pkt.window, np.float64)
            if w.ndim == 3 and w.shape[1:] == (pkt.world_size, len(pkt.stages)):
                job.streaming.push_many(w)
                self._fold_regimes(job, pkt, w)
                # f32 is what the kernel consumes; half the pinned bytes,
                # and refresh_batched() releases it after the refresh.
                job.last_window = w.astype(np.float32)
        return job

    def _fold_regimes(
        self, job: JobState, pkt: EvidencePacket, w: np.ndarray
    ) -> None:
        """Fold one raw window into the job's temporal regime stream.

        The stream is only meaningful over a *contiguous* step history
        with a *fixed* imputation profile, so it restarts (never
        silently stitches) when either breaks:

          * the declared sync profile changed since the stream was
            built — the excess rows' imputation semantics changed, so
            old history is not comparable (same contract as
            `StreamingRegimes.rebase`);
          * the packet's declared `first_step` does not equal the next
            expected step — a dropped window, a compact packet in
            between, or reordering; stitching non-adjacent steps would
            corrupt onsets and promote two distant bursts into one
            contiguous run.  Legacy packets (`first_step == -1`) cannot
            declare coordinates and are folded as contiguous.
        """
        sync_key = tuple(job.sync_stages)
        if job.regimes is not None and sync_key != job.regime_sync:
            job.regimes = None
        if job.regimes is not None and pkt.first_step >= 0:
            expected = job.step_origin + job.regimes.steps_seen
            if pkt.first_step != expected:
                job.regimes = None
        if job.regimes is None:
            # reference fixed from this window's cohort median of the
            # sync-imputed work (the same default the batch engine
            # derives); later windows fold against it so early/late
            # folds agree.  float32 ring: at fleet scale the excess
            # history is the registry's dominant pinned state, and the
            # classification thresholds are far above f32 resolution
            # (the engine-level bit-for-bit contract is property-tested
            # at the default float64).
            from ..core.regimes import excess_stream

            mask = (
                make_sync_mask(job.stages, job.sync_stages)
                if job.sync_stages
                else None
            )
            _, base = excess_stream(w, sync_mask=mask)
            job.regimes = StreamingRegimes(
                job.world_size,
                len(job.stages),
                base,
                capacity=self.window_capacity * self.regime_windows,
                sync_mask=mask,
                dtype=np.float32,
            )
            job.step_origin = max(0, pkt.first_step)
            job.regime_sync = sync_key
        job.regimes.push_many(w)

    def evict_stale(self, tick: int) -> list[str]:
        """Drop jobs silent for >= evict_after ticks; returns evicted ids."""
        stale = [
            jid for jid, j in self._jobs.items()
            if tick - j.last_tick >= self.evict_after
        ]
        for jid in stale:
            del self._jobs[jid]
        return stale

    # -- reads -------------------------------------------------------------

    def get(self, job_id: str) -> JobState | None:
        return self._jobs.get(job_id)

    def jobs(self) -> list[JobState]:
        return list(self._jobs.values())

    def dirty_groups(self) -> dict[tuple, list[JobState]]:
        """Dirty window-carrying jobs grouped by batching key.

        Dirty = a raw window arrived since the last kernel refresh (the
        registry nulls `kernel_shares` on ingest).  Jobs are grouped by
        (window shape, declared sync profile): windows stack into one
        [J, N, R, S] tensor only when shapes agree, and the sync
        segmentation is a static kernel argument that must match within
        a batch.  Degraded jobs are skipped — their telemetry is not
        trusted enough to spend kernel time on."""
        groups: dict[tuple, list[JobState]] = {}
        for job in self._jobs.values():
            if (
                job.last_window is not None
                and not job.degraded
                and job.kernel_shares is None
            ):
                key = (job.last_window.shape, job.sync_index_tuple())
                groups.setdefault(key, []).append(job)
        return groups

    def __len__(self) -> int:
        return len(self._jobs)

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs
