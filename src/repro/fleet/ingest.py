"""Fleet wire ingest: failure-safe evidence-packet decoding.

The fleet boundary is hostile by construction — thousands of jobs ship
packets over flaky transports, versions skew, payloads truncate.  The
ingest layer applies the same contract as the telemetry gather (§5):
malformed input is *counted and dropped*, never raised into the service
loop.  Both wire framings are accepted (SFP2 and the legacy SFP1), in
raw float64, per-stage int8, and int8 delta+varint payload codecs — the
codecs shared with `repro.distributed.compression`.

`decode_many` is the batched tick path: one call decodes a whole tick's
wire blobs and feeds `FleetService.submit_many` -> `refresh_batched`
without intermediate copies — SFP2 float64 windows land as read-only
zero-copy views into their wire buffers and are only materialized once,
by the registry's single `float32` cast for the batched kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from ..telemetry.packets import EvidencePacket, decode_packet

__all__ = ["FleetIngest", "IngestStats"]


@dataclasses.dataclass
class IngestStats:
    """Wire-boundary counters.

    `packets` counts every accepted submission; `predecoded` is the
    subset that arrived as in-process `EvidencePacket` objects (no wire
    bytes — same-process emitters).  `bytes` only ever counts real wire
    bytes, so `avg_wire_bytes` stays a transport number instead of being
    dragged toward zero by pre-decoded submissions.
    """

    packets: int = 0
    bytes: int = 0
    decode_errors: int = 0
    #: accepted submissions that were already-decoded EvidencePackets
    predecoded: int = 0

    @property
    def wire_packets(self) -> int:
        """Accepted packets that actually crossed the wire."""
        return self.packets - self.predecoded

    @property
    def error_ratio(self) -> float:
        """Decode failures per wire submission.  Pre-decoded packets never
        touch the decoder, so they are excluded — 90 in-process
        submissions must not dilute 10 bad blobs out of 20 wire packets
        down from 50% to 9%."""
        total = self.wire_packets + self.decode_errors
        return self.decode_errors / total if total else 0.0

    @property
    def avg_wire_bytes(self) -> float:
        """Mean wire size of decoded packets (0.0 before any arrive)."""
        wp = self.wire_packets
        return self.bytes / wp if wp else 0.0


class FleetIngest:
    """Stateless decoder with drop counters (the fleet's gather contract)."""

    def __init__(self):
        self.stats = IngestStats()

    def decode(self, data: bytes | EvidencePacket) -> EvidencePacket | None:
        """Decode one wire payload; returns None (and counts) on any error."""
        if isinstance(data, EvidencePacket):
            self.stats.packets += 1
            self.stats.predecoded += 1
            return data
        try:
            pkt = decode_packet(bytes(data))
        except Exception:
            self.stats.decode_errors += 1
            return None
        self.stats.packets += 1
        self.stats.bytes += len(data)
        return pkt

    def decode_many(
        self, blobs: Iterable[bytes | EvidencePacket]
    ) -> list[EvidencePacket | None]:
        """Decode a tick's worth of payloads, position-aligned with the
        input (None where a blob was dropped); counters update exactly as
        `decode` would."""
        return [self.decode(b) for b in blobs]
