"""Fleet wire ingest: failure-safe evidence-packet decoding.

The fleet boundary is hostile by construction — thousands of jobs ship
packets over flaky transports, versions skew, payloads truncate.  The
ingest layer applies the same contract as the telemetry gather (§5):
malformed input is *counted and dropped*, never raised into the service
loop.  Both wire encodings are accepted: raw float64 windows and the
per-stage symmetric-int8 compressed form (the codec shared with
`repro.distributed.compression`).
"""
from __future__ import annotations

import dataclasses

from ..telemetry.packets import EvidencePacket, decode_packet

__all__ = ["FleetIngest", "IngestStats"]


@dataclasses.dataclass
class IngestStats:
    packets: int = 0
    bytes: int = 0
    decode_errors: int = 0

    @property
    def error_ratio(self) -> float:
        total = self.packets + self.decode_errors
        return self.decode_errors / total if total else 0.0


class FleetIngest:
    """Stateless decoder with drop counters (the fleet's gather contract)."""

    def __init__(self):
        self.stats = IngestStats()

    def decode(self, data: bytes | EvidencePacket) -> EvidencePacket | None:
        """Decode one wire payload; returns None (and counts) on any error."""
        if isinstance(data, EvidencePacket):
            self.stats.packets += 1
            return data
        try:
            pkt = decode_packet(bytes(data))
        except Exception:
            self.stats.decode_errors += 1
            return None
        self.stats.packets += 1
        self.stats.bytes += len(data)
        return pkt
