"""Logical-axis sharding rules (MaxText-style) -> NamedSharding trees.

Every parameter declares logical axes at init time (see models/*_axes);
a `ShardingPlan` maps logical names to mesh axes.  Conflicts (two logical
axes of one tensor mapping to the same mesh axis) are resolved
first-come-first-served along the dims, so e.g. MoE weights
(expert, embed, mlp) with expert->model and mlp->model shard over experts
and leave mlp replicated — expert parallelism wins on expert tensors.

Plans are data, not code: the perf hillclimb (EXPERIMENTS.md §Perf) swaps
plans without touching the models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingPlan",
    "BASELINE_PLAN",
    "DECODE_PLAN",
    "DP_ALL_PLAN",
    "DP_FSDP_PLAN",
    "sharding_for_axes",
    "tree_shardings",
    "batch_sharding",
    "shard_placements",
]

MeshAxes = tuple[str, ...] | str | None


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """logical axis name -> mesh axis (or axes tuple, or None=replicate)."""

    name: str
    rules: Mapping[str, MeshAxes]
    #: mesh axes carrying the batch dimension of activations.
    batch_axes: tuple[str, ...] = ("pod", "data")
    #: mesh axes carrying the sequence dim of activations ("" = unsharded).
    seq_axes: tuple[str, ...] = ()
    #: mesh axes for the KV-cache sequence dim in decode.
    cache_seq_axes: tuple[str, ...] = ("model",)

    def lookup(self, logical: str | None) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical)


#: Baseline plan: textbook Megatron TP over `model` (column-parallel wi /
#: wq-k-v, row-parallel wo/wd with one activation all-reduce each),
#: vocab-parallel embedding, DP over data (and pods), experts
#: expert-parallel over `model` with their hidden dim 2D-sharded over
#: `data` (fits 100B-scale MoE + optimizer state per device).  Weights are
#: deliberately NOT sharded on contraction dims over `data`: that induces
#: partial-sum activation all-reduces (measured 1.5 TB/device on
#: granite/train_4k — see EXPERIMENTS.md §Perf iteration 0).
BASELINE_PLAN = ShardingPlan(
    name="tp16-dp16",
    rules={
        "vocab": "model",
        "embed": None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "expert_mlp": "data",
        "layer": None,
    },
)

#: Decode-oriented plan: weights replicated over `data` (decode is
#: latency-bound; FSDP all-gathers per token would dominate), TP over model,
#: KV-cache sequence sharded over `model` (sequence-parallel attention).
DECODE_PLAN = ShardingPlan(
    name="decode-tp16",
    rules={
        "vocab": "model",
        "embed": None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "expert": "model",
        "expert_mlp": "data",
        "layer": None,
    },
)


#: Pure data parallelism over the whole mesh: every weight replicated,
#: batch sharded over all axes.  The §Perf hillclimb winner for small-model
#: training cells (TP at d_model ~2k is collective-bound at 256 chips).
DP_ALL_PLAN = ShardingPlan(
    name="dp256",
    rules={"layer": None},
    batch_axes=("pod", "data", "model"),
)


#: Weight-gather FSDP: batch over ALL mesh axes (DP256), weights STORED
#: sharded over `model`; GSPMD all-gathers the (small) weights at use and
#: reduce-scatters their grads — params/grads/optimizer state shrink 16x
#: vs dp256 while collectives stay weight-sized (§Perf iteration A6).
DP_FSDP_PLAN = ShardingPlan(
    name="dp-fsdp16",
    rules=dict(BASELINE_PLAN.rules),
    batch_axes=("pod", "data", "model"),
)


def _axes_filter(mesh: Mesh, axes: MeshAxes, used: set[str]) -> MeshAxes:
    """Drop mesh axes not present in the mesh or already used by this tensor."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    picked = tuple(a for a in axes if a in mesh.axis_names and a not in used)
    used.update(picked)
    if not picked:
        return None
    return picked if len(picked) > 1 else picked[0]


def spec_for_axes(
    mesh: Mesh, logical_axes: Sequence[str | None], plan: ShardingPlan
) -> P:
    used: set[str] = set()
    dims = []
    for logical in logical_axes:
        dims.append(_axes_filter(mesh, plan.lookup(logical), used))
    return P(*dims)


def sharding_for_axes(
    mesh: Mesh, logical_axes: Sequence[str | None], plan: ShardingPlan
) -> NamedSharding:
    return NamedSharding(mesh, spec_for_axes(mesh, logical_axes, plan))


def tree_shardings(
    mesh: Mesh, axes_tree: Any, plan: ShardingPlan, spec_tree: Any = None
) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings.

    With `spec_tree` (matching ShapeDtypeStructs), shardings are
    shape-sanitized: any dim whose size is not divisible by its mesh-axes
    product is replicated instead (jit rejects uneven input shardings, and
    padded weights cost more in churn than the sharding saves).
    """
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )
    if spec_tree is None:
        return jax.tree.map(
            lambda axes: sharding_for_axes(mesh, axes, plan),
            axes_tree,
            is_leaf=is_axes,
        )

    def leaf(axes, spec):
        sh = sharding_for_axes(mesh, axes, plan)
        dims = list(sh.spec) + [None] * (len(spec.shape) - len(sh.spec))
        changed = False
        for i, (dim, size) in enumerate(zip(dims, spec.shape)):
            if dim is None:
                continue
            axes_i = (dim,) if isinstance(dim, str) else dim
            prod = 1
            for a in axes_i:
                prod *= mesh.shape[a]
            if size % prod != 0:
                dims[i] = None
                changed = True
        return NamedSharding(mesh, P(*dims)) if changed else sh

    return jax.tree.map(leaf, axes_tree, spec_tree, is_leaf=is_axes)


def batch_sharding(
    mesh: Mesh, ndim: int, plan: ShardingPlan, *, seq_dim: int | None = 1
) -> NamedSharding:
    """Batch-dim sharding for an activation/batch tensor of rank `ndim`."""
    used: set[str] = set()
    dims: list[MeshAxes] = [_axes_filter(mesh, plan.batch_axes, used)]
    for d in range(1, ndim):
        if d == seq_dim and plan.seq_axes:
            dims.append(_axes_filter(mesh, plan.seq_axes, used))
        else:
            dims.append(None)
    return NamedSharding(mesh, P(*dims))


def cache_sharding(
    mesh: Mesh, spec_shape: tuple[int, ...], plan: ShardingPlan,
    *, seq_dim: int = 2,
) -> NamedSharding:
    """KV-cache sharding: batch over DP axes, cache sequence over
    `cache_seq_axes` (sequence-parallel decode attention).  seq_dim=2 for
    the [L,B,S,KV,D] layout, 3 for head-major [L,B,KV,S,D]."""
    used: set[str] = set()
    batch = _axes_filter(mesh, plan.batch_axes, used)
    seq = _axes_filter(mesh, plan.cache_seq_axes, used)
    dims: list[MeshAxes] = [None, batch] + [None] * (len(spec_shape) - 2)
    dims[seq_dim] = seq
    return NamedSharding(mesh, P(*dims))


def shard_placements(mesh: Mesh, shards: int) -> tuple:
    """Round-robin device assignment of `shards` logical fleet shards
    onto a ``shard``-axis mesh (`launch.mesh.make_fleet_mesh`).

    Placement is data, not code — the same policy discipline as the
    `ShardingPlan` tables above: shard i refreshes on
    ``mesh.devices.flat[i % len]``, so N shards on an N-device rig get
    one device each and a larger fleet wraps around deterministically.
    """
    devs = list(mesh.devices.flat)
    if not devs:
        raise ValueError("mesh has no devices")
    return tuple(devs[i % len(devs)] for i in range(max(0, int(shards))))


def ssm_cache_sharding(
    mesh: Mesh, spec_shape: tuple[int, ...], plan: ShardingPlan
) -> NamedSharding:
    """SSM state [L, B, H, P, N] / conv [L, B, W, C]: batch over DP axes."""
    used: set[str] = set()
    batch = _axes_filter(mesh, plan.batch_axes, used)
    dims: list[MeshAxes] = [None, batch] + [None] * (len(spec_shape) - 2)
    return NamedSharding(mesh, P(*dims))
