"""Operational policy driven by StageFrontier labels (beyond-paper layer).

The paper stops at "route the operator / heavy profiler to (window, stage,
rank)".  Because this framework owns the training loop, the label stream
drives concrete actions — always respecting the evidence semantics: labels
that scope ambiguity (`co_critical`, `role_aware_needed`) or telemetry
quality (`telemetry_limited`) never trigger workload-touching actions.

Actions are *proposals*: the train loop executes TriggerProfiler itself and
surfaces the rest (rank quarantine needs rank->host mapping, which the paper
explicitly warns about — "a recurrent rank is not a node").
"""
from __future__ import annotations

import dataclasses
from collections import deque

from ..core.labeler import (
    CO_CRITICAL,
    DIRECT_EXPOSURE,
    LIKELY_SYNC_WAIT,
    SYNC_WAIT_DEPENDENT,
    TELEMETRY_LIMITED,
)
from ..core.windows import WindowReport

__all__ = ["Action", "MonitorPolicy"]

STRONG_LABELS = (DIRECT_EXPOSURE, SYNC_WAIT_DEPENDENT, LIKELY_SYNC_WAIT)


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str           # trigger_profiler | rebalance_data | quarantine_rank
    #                   # | checkpoint_reshard | none
    window_index: int
    stage: str = ""
    rank: int = -1
    reason: str = ""


@dataclasses.dataclass
class MonitorPolicy:
    """Stateful window-report consumer."""

    #: consecutive telemetry_limited windows with missing ranks before
    #: promoting fail-slow to fail-stop (checkpoint + reshard proposal).
    reshard_after: int = 3
    #: consecutive windows a unique leader rank must persist before a
    #: rank-scoped action is proposed.
    leader_persistence: int = 2
    profiler_cooldown: int = 5

    def __post_init__(self):
        self._missing_streak = 0
        self._leader_history: deque[int] = deque(maxlen=max(2, self.leader_persistence))
        self._last_profile_window = -(10**9)

    def on_report(self, report: WindowReport) -> list[Action]:
        diag = report.diagnosis
        w = report.window_index
        actions: list[Action] = []

        # ---- telemetry-quality track: fail-slow -> fail-stop promotion ----
        if diag.has(TELEMETRY_LIMITED) and not diag.gather_ok:
            self._missing_streak += 1
            if self._missing_streak >= self.reshard_after:
                actions.append(
                    Action(
                        kind="checkpoint_reshard",
                        window_index=w,
                        reason=(
                            f"{self._missing_streak} consecutive windows with "
                            "failed telemetry gather: treat as node fail-slow"
                        ),
                    )
                )
                self._missing_streak = 0
            return actions  # degraded telemetry: no workload actions
        self._missing_streak = 0

        strong = [l for l in diag.labels if l in STRONG_LABELS]
        leader = diag.leader.leader_rank if diag.leader else -1
        self._leader_history.append(leader)
        persistent_leader = (
            leader >= 0
            and len(self._leader_history) >= self.leader_persistence
            and len(set(list(self._leader_history)[-self.leader_persistence:])) == 1
        )

        # ---- profiler routing: strong stage evidence arms a heavy trace ----
        if strong and w - self._last_profile_window >= self.profiler_cooldown:
            actions.append(
                Action(
                    kind="trigger_profiler",
                    window_index=w,
                    stage=diag.routing_stages[0] if diag.routing_stages else "",
                    rank=leader,
                    reason=f"labels={strong} routing={diag.routing_stages[:2]}",
                )
            )
            self._last_profile_window = w

        # ---- straggler mitigation: data-routed persistent unique leader ----
        if (
            persistent_leader
            and diag.routing_stages
            and diag.routing_stages[0].startswith("data.")
            and (strong or diag.has(CO_CRITICAL))
        ):
            actions.append(
                Action(
                    kind="rebalance_data",
                    window_index=w,
                    stage=diag.routing_stages[0],
                    rank=leader,
                    reason=f"persistent data-stage frontier leader rank {leader}",
                )
            )
        return actions
