"""Int8 gradient compression with error feedback (cross-pod DCN saver).

Per-tensor symmetric int8 quantization of gradients with an error-feedback
accumulator: the quantization residual is carried into the next step, so the
compressed optimizer converges to the uncompressed trajectory (Karimireddy
et al.-style EF-SGD argument).  On a multi-pod deployment the pod-axis
gradient all-reduce moves int8 payloads (4x DCN reduction at bf16 master
grads); in this repo the transform is exact-math-tested and wired as an
optional grad transform in the train step (`--grad-compression int8`).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "EFState",
    "init_ef",
    "compress_grads",
    "quantize_i8",
    "dequantize_i8",
]


class EFState(NamedTuple):
    error: Any  # residual tree, f32


def init_ef(params: Any) -> EFState:
    return EFState(
        error=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    )


# ---------------------------------------------------------------------------
# Symmetric int8 codec (shared math: gradient transform AND the fleet
# telemetry wire format in repro.telemetry.packets / repro.fleet.ingest)
# ---------------------------------------------------------------------------


def quantize_i8(
    x: np.ndarray, *, axis: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization: q = round(x / scale), scale = amax/127.

    `axis=None` is the per-tensor scale of the gradient path; the telemetry
    wire format passes the stage axis so each stage column keeps its own
    dynamic range (a 100 ms backward must not flatten a 2 ms residual).

    Returns (q int8 same-shape, scale float64 — scalar or per-slice).
    """
    xf = np.asarray(x, np.float64)
    amax = np.abs(xf).max() if axis is None else np.abs(xf).max(
        axis=tuple(i for i in range(xf.ndim) if i != axis % xf.ndim),
        keepdims=False,
    )
    scale = np.maximum(amax, 1e-12) / 127.0
    s = scale if axis is None else np.expand_dims(
        scale, tuple(i for i in range(xf.ndim) if i != axis % xf.ndim)
    )
    q = np.clip(np.round(xf / s), -127, 127).astype(np.int8)
    return q, scale


def dequantize_i8(
    q: np.ndarray, scale: np.ndarray, *, axis: int | None = None
) -> np.ndarray:
    """Inverse of `quantize_i8` (up to the quantization error)."""
    qf = np.asarray(q, np.float64)
    if axis is None:
        return qf * float(scale)
    s = np.expand_dims(
        np.asarray(scale, np.float64),
        tuple(i for i in range(qf.ndim) if i != axis % qf.ndim),
    )
    return qf * s


def _quantize_dequantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 round-trip; returns (dequantized, residual)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def compress_grads(grads: Any, ef: EFState) -> tuple[Any, EFState]:
    """Apply EF-int8 to every gradient leaf.

    returns (compressed grads to feed the optimizer, updated error state).
    """
    carried = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef.error)
    deq_and_res = jax.tree.map(_quantize_dequantize, carried)
    deq = jax.tree.map(lambda t: t[0], deq_and_res, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], deq_and_res, is_leaf=lambda x: isinstance(x, tuple))
    return deq, EFState(error=res)
