"""Int8 gradient compression with error feedback (cross-pod DCN saver).

Per-tensor symmetric int8 quantization of gradients with an error-feedback
accumulator: the quantization residual is carried into the next step, so the
compressed optimizer converges to the uncompressed trajectory (Karimireddy
et al.-style EF-SGD argument).  On a multi-pod deployment the pod-axis
gradient all-reduce moves int8 payloads (4x DCN reduction at bf16 master
grads); in this repo the transform is exact-math-tested and wired as an
optional grad transform in the train step (`--grad-compression int8`).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "EFState",
    "init_ef",
    "compress_grads",
    "quantize_i8",
    "dequantize_i8",
    "delta_varint_encode_i8",
    "delta_varint_decode_i8",
]


class EFState(NamedTuple):
    error: Any  # residual tree, f32


def init_ef(params: Any) -> EFState:
    return EFState(
        error=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    )


# ---------------------------------------------------------------------------
# Symmetric int8 codec (shared math: gradient transform AND the fleet
# telemetry wire format in repro.telemetry.packets / repro.fleet.ingest)
# ---------------------------------------------------------------------------


def quantize_i8(
    x: np.ndarray, *, axis: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric int8 quantization: q = round(x / scale), scale = amax/127.

    `axis=None` is the per-tensor scale of the gradient path; the telemetry
    wire format passes the stage axis so each stage column keeps its own
    dynamic range (a 100 ms backward must not flatten a 2 ms residual).

    Returns (q int8 same-shape, scale float64 — scalar or per-slice).
    """
    xf = np.asarray(x, np.float64)
    if axis is None:
        amax = np.abs(xf).max()
    else:
        # successive leading-axis maxes are bit-identical to the joint
        # reduction but keep every pass contiguous — the joint
        # max(axis=(0, 1)) form is ~6x slower on [N, R, S] windows (it
        # reduces down strided stage columns), and this sits on the
        # evidence-packet encode hot path.
        amax = np.moveaxis(np.abs(xf), axis % xf.ndim, -1)
        while amax.ndim > 1:
            amax = amax.max(axis=0)
    scale = np.maximum(amax, 1e-12) / 127.0
    s = scale if axis is None else np.expand_dims(
        scale, tuple(i for i in range(xf.ndim) if i != axis % xf.ndim)
    )
    # same values as clip(round(x / s)) with two fewer temporaries
    q = xf / s
    np.rint(q, out=q)
    np.clip(q, -127, 127, out=q)
    return q.astype(np.int8), scale


def dequantize_i8(
    q: np.ndarray, scale: np.ndarray, *, axis: int | None = None
) -> np.ndarray:
    """Inverse of `quantize_i8` (up to the quantization error)."""
    qf = np.asarray(q, np.float64)
    if axis is None:
        return qf * float(scale)
    s = np.expand_dims(
        np.asarray(scale, np.float64),
        tuple(i for i in range(qf.ndim) if i != axis % qf.ndim),
    )
    return qf * s


# ---------------------------------------------------------------------------
# Step-axis delta + zigzag-varint codec for int8 windows (the SFP2 wire
# payload in repro.telemetry.packets).  Deltas are taken along the leading
# (step) axis independently per trailing cell, so each stage column keeps
# its own smooth stream; zigzagged deltas of int8 values span [0, 508] and
# therefore fit LEB128 varints of at most two bytes, which is what lets
# both directions stay fully numpy-vectorized.
# ---------------------------------------------------------------------------


def _varint_encode_u16(vals: np.ndarray) -> bytes:
    """LEB128-encode a flat array of values < 2**14 (<= 2 bytes each)."""
    v = np.asarray(vals, np.uint16).ravel()
    if v.size == 0:
        return b""
    two = v >= 0x80
    # interleaved (low, high) byte planes; boolean compress keeps the low
    # byte always and the high byte only for two-byte values, in C order —
    # one pass instead of a cumsum + two scatters.
    pair = np.empty((v.size, 2), np.uint8)
    pair[:, 0] = (v & 0x7F) | (two << 7)
    pair[:, 1] = v >> 7
    keep = np.empty((v.size, 2), bool)
    keep[:, 0] = True
    keep[:, 1] = two
    return pair[keep].tobytes()


def _varint_decode_u16(buf: np.ndarray, count: int) -> np.ndarray:
    """Inverse of `_varint_encode_u16`; strict: the buffer must hold exactly
    `count` well-formed varints (truncation, over-length varints and
    trailing bytes all raise ValueError)."""
    b = np.asarray(buf, np.uint8).ravel()
    if count == 0:
        if b.size:
            raise ValueError("varint stream has trailing bytes")
        return np.zeros(0, np.uint32)
    if b.size == 0 or (b[-1] & 0x80):
        raise ValueError("truncated varint stream")
    cont = (b & 0x80) != 0
    starts_mask = np.empty(b.size, bool)
    starts_mask[0] = True
    np.logical_not(cont[:-1], out=starts_mask[1:])
    starts = np.flatnonzero(starts_mask)
    if starts.size != count:
        raise ValueError(
            f"varint stream holds {starts.size} values, expected {count}"
        )
    vals = (b[starts] & 0x7F).astype(np.uint16)
    two = cont[starts]
    second = b[starts[two] + 1]
    if (second & 0x80).any():
        raise ValueError("varint longer than 2 bytes")
    vals[two] |= second.astype(np.uint16) << 7
    return vals


def delta_varint_encode_i8(q: np.ndarray) -> bytes:
    """Delta the int8 array `q` along its leading (step) axis per trailing
    cell, zigzag, and LEB128-encode.  Lossless: `delta_varint_decode_i8`
    recovers `q` exactly."""
    qi = np.asarray(q, np.int8).astype(np.int16)
    d = np.diff(qi, axis=0, prepend=np.zeros((1, *qi.shape[1:]), np.int16))
    z = (d << 1) ^ (d >> 15)  # zigzag: [-254, 254] -> [0, 508]
    return _varint_encode_u16(z)


def delta_varint_decode_i8(buf, shape: tuple[int, ...]) -> np.ndarray:
    """Inverse of `delta_varint_encode_i8` for a declared `shape`.  Strict:
    raises ValueError on truncation, trailing bytes, or any prefix sum
    escaping the int8 range (corrupt deltas never wrap silently)."""
    shape = tuple(int(s) for s in shape)
    n = int(np.prod(shape)) if shape else 0
    z = _varint_decode_u16(np.frombuffer(buf, np.uint8), n).astype(np.int32)
    d = (z >> 1) ^ -(z & 1)  # un-zigzag
    q = np.cumsum(d.reshape(shape), axis=0, dtype=np.int32) if n else \
        np.zeros(shape, np.int32)
    if n and (q.min() < -128 or q.max() > 127):
        raise ValueError("delta stream escapes int8 range (corrupt payload)")
    return q.astype(np.int8)


def _quantize_dequantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 round-trip; returns (dequantized, residual)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def compress_grads(grads: Any, ef: EFState) -> tuple[Any, EFState]:
    """Apply EF-int8 to every gradient leaf.

    returns (compressed grads to feed the optimizer, updated error state).
    """
    carried = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef.error)
    deq_and_res = jax.tree.map(_quantize_dequantize, carried)
    deq = jax.tree.map(lambda t: t[0], deq_and_res, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], deq_and_res, is_leaf=lambda x: isinstance(x, tuple))
    return deq, EFState(error=res)
