"""Distribution: sharding plans, operational policy, gradient compression."""
from .sharding import BASELINE_PLAN, DECODE_PLAN, ShardingPlan, tree_shardings
from .policy import Action, MonitorPolicy
from .compression import EFState, compress_grads, init_ef

__all__ = [
    "Action",
    "BASELINE_PLAN",
    "DECODE_PLAN",
    "EFState",
    "MonitorPolicy",
    "ShardingPlan",
    "compress_grads",
    "init_ef",
    "tree_shardings",
]
