"""End-to-end training driver with always-on StageFrontier monitoring.

    PYTHONPATH=src python -m repro.launch.train \
        --arch paper-gpt-125m --steps 200 --batch 8 --seq 512 \
        --ckpt-dir /tmp/ckpt --resume auto --window 50

Fused-step taxonomy (DESIGN.md §3): data.next_wait / step.dispatch /
step.device_wait / callbacks / ckpt / residual.  The monitor gathers
windows, labels them, emits evidence packets, and the policy can arm a
one-window `jax.profiler` trace (the paper's router-to-profiler loop).
Checkpoint/restart: `--resume auto` restarts from the newest valid
manifest, including the data-pipeline cursor.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.ckpt import latest_step, restore_checkpoint, save_checkpoint
from ..configs import get_config
from ..core.contract import fused_schema
from ..data.pipeline import PrefetchPipeline, SyntheticTokens
from ..distributed.policy import Action
from ..distributed.sharding import BASELINE_PLAN, ShardingPlan
from ..models import build_model
from ..optim.adamw import AdamWConfig
from ..telemetry.collector import Monitor
from .mesh import make_local_mesh
from .steps import TrainState, build_train_step, init_train_state


def make_argparser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="paper-gpt-125m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--reduced", action="store_true", help="smoke-scale config")
    p.add_argument("--window", type=int, default=50)
    p.add_argument("--event-q", type=float, default=0.05)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--resume", default="no", choices=["no", "auto"])
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--profile-dir", default="", help="arm router-triggered traces")
    p.add_argument("--data-stall-ms", type=float, default=0.0,
                   help="inject a data-pipeline stall every 10 steps (demo)")
    p.add_argument("--log-every", type=int, default=20)
    return p


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg,
        attn_q_chunk=min(cfg.attn_q_chunk, args.seq),
        attn_kv_chunk=min(cfg.attn_kv_chunk, args.seq),
        ssm_chunk=min(cfg.ssm_chunk, args.seq),
    )
    model = build_model(cfg)
    mesh = make_local_mesh()
    schema = fused_schema(world_size=1)

    profile_state = {"active_until": -1}

    def on_action(action: Action) -> None:
        print(f"[policy] {action.kind}: {action.reason}")
        if action.kind == "trigger_profiler" and args.profile_dir:
            os.makedirs(args.profile_dir, exist_ok=True)
            jax.profiler.start_trace(args.profile_dir)
            profile_state["active_until"] = step_counter["i"] + 10

    monitor = Monitor(
        schema,
        window_steps=args.window,
        event_q=args.event_q,
        on_action=on_action,
    )

    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=max(10, args.steps // 20),
                          decay_steps=args.steps)
    with mesh:
        train_step, state_sh = build_train_step(
            model, mesh, BASELINE_PLAN, opt_cfg, accum_steps=args.accum
        )
        state = init_train_state(model, jax.random.PRNGKey(0))

        start = 0
        if args.resume == "auto" and args.ckpt_dir:
            restored = restore_checkpoint(args.ckpt_dir, state)
            if restored is not None:
                state, extra, start = restored
                state = jax.tree.map(jnp.asarray, state)
                print(f"[ckpt] resumed from step {start}")

        stall = None
        if args.data_stall_ms > 0:
            stall = lambda s: (args.data_stall_ms / 1e3) if s % 10 == 0 else 0.0
        source = SyntheticTokens(cfg.vocab_size, args.batch, args.seq, seed=1)
        pipeline = PrefetchPipeline(source, start_cursor=start, stall=stall)

        losses = []
        step_counter = {"i": start}
        prev_metrics = None
        t_train0 = time.perf_counter()
        try:
            for i in range(start, args.steps):
                step_counter["i"] = i
                with monitor.step():
                    with monitor.stage("data.next_wait"):
                        # host staging is part of the data path: charged here
                        host_batch = next(pipeline)
                        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
                    t_dispatch = time.perf_counter()
                    with monitor.stage("step.dispatch_cpu_wall"):
                        state, metrics = train_step(state, batch)
                    monitor.observe_output(
                        metrics["loss"], (time.perf_counter() - t_dispatch) * 1e3
                    )
                    with monitor.stage("step.device_wait_cpu_wall"):
                        # fetch the PREVIOUS step's metrics: this is where
                        # device time becomes host-visible (sync displacement
                        # lands here) while this step's work proceeds async.
                        if prev_metrics is not None:
                            losses.append(float(prev_metrics["loss"]))
                        prev_metrics = metrics
                    with monitor.stage("callbacks.cpu_wall"):
                        if i % args.log_every == 0 and losses:
                            print(f"step {i}: loss {losses[-1]:.4f}")
                    with monitor.stage("ckpt.cpu_wall"):
                        if args.ckpt_dir and i and i % args.ckpt_every == 0:
                            save_checkpoint(
                                args.ckpt_dir,
                                i,
                                jax.device_get(state),
                                extra={"data": pipeline.state()},
                            )
                monitor.end_of_step()
                if profile_state["active_until"] == i:
                    jax.profiler.stop_trace()
                    profile_state["active_until"] = -1
                    print(f"[policy] heavy trace captured to {args.profile_dir}")
            losses.append(float(jax.device_get(prev_metrics["loss"])))
        finally:
            pipeline.close()
            if profile_state["active_until"] >= 0:
                jax.profiler.stop_trace()
        train_seconds = time.perf_counter() - t_train0
        if args.ckpt_dir:
            save_checkpoint(
                args.ckpt_dir, args.steps, jax.device_get(state),
                extra={"data": pipeline.state()},
            )

    reports = monitor.aggregator.reports
    summary = {
        "arch": cfg.name,
        "steps": args.steps - start,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "train_seconds": train_seconds,
        "monitor_overhead": monitor.overhead_fraction(train_seconds),
        "windows": [
            {
                "index": r.window_index,
                "labels": list(r.diagnosis.labels),
                "routing": list(r.diagnosis.routing_stages),
                "shares": [round(s, 4) for s in r.diagnosis.shares],
            }
            for r in reports
        ],
        "actions": [dataclasses.asdict(a) for a in monitor.actions],
    }
    return summary


def main() -> None:
    args = make_argparser().parse_args()
    summary = run(args)
    print(json.dumps(summary, indent=2, default=str))


if __name__ == "__main__":
    main()
