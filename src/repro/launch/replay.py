"""Trace-driven fleet replay driver.

    PYTHONPATH=src python -m repro.launch.replay --trace cluster.jsonl --wire sfp2
    PYTHONPATH=src python -m repro.launch.replay --synth --jobs 12 --ticks 16

Loads a JSONL cluster trace (or generates the deterministic synthetic
one, `--synth`) and replays it through the fleet aggregation service:
each trace tick, every live job's window is simulated with the trace's
injected faults, aggregated, wire-encoded, and driven through the same
submit_many -> refresh -> tick -> route path as `serve_fleet`.  Prints
the machine-readable replay report (`repro.replay.ReplayReport`):
replay volume, elastic-churn counters, per-family routing accuracy
against the trace's injected ground truth, loader skip statistics, and
the final service snapshot.

`--save-trace PATH` additionally writes the generated synthetic trace
to disk (a convenient way to produce a trace file to inspect or to
corrupt for fuzzing); `--out PATH` writes the report JSON to a file as
well as stdout.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..replay import generate_trace, load_trace, parse_trace, replay_trace


def make_argparser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    src = p.add_mutually_exclusive_group(required=True)
    src.add_argument("--trace", default="",
                     help="JSONL trace file to replay")
    src.add_argument("--synth", action="store_true",
                     help="generate + replay the deterministic synthetic "
                          "trace (see --jobs/--ticks/...)")
    p.add_argument("--wire", default="sfp2", choices=["sfp1", "sfp2"])
    p.add_argument("--compress", default="int8",
                   choices=["none", "int8", "int8.delta"])
    p.add_argument("--top-k", type=int, default=2)
    p.add_argument("--evict-after", type=int, default=3)
    p.add_argument("--incidents", action="store_true",
                   help="attach the durable incident tier during replay")
    p.add_argument("--tick-path", default="fused",
                   choices=["fused", "four-dispatch"],
                   help="kernel refresh route: the fused megakernel or "
                        "the four-dispatch reference (bit-identical; "
                        "four-dispatch is the triage fallback)")
    p.add_argument("--shards", type=int, default=None,
                   help="replay through an N-shard ShardedFleetService "
                        "(stable job-id hash partition; the report is "
                        "bit-identical to the unsharded replay outside "
                        "wall-clock fields)")
    p.add_argument("--shard-workers", default="thread",
                   choices=["thread", "inline"],
                   help="per-shard lanes under --shards (thread = "
                        "overlapped decode/dispatch, inline = "
                        "sequential reference)")
    p.add_argument("--obs", default=True,
                   action=argparse.BooleanOptionalAction,
                   help="self-observability (repro.obs) on the replay "
                        "service: the report gains an 'obs' section "
                        "(tick-phase frontier + slowest-shard "
                        "attribution, metrics, flight-recorder stats — "
                        "docs/observability.md).  On by default; "
                        "--no-obs is the overhead-benchmark control arm")
    # synthetic-trace shape (ignored with --trace)
    p.add_argument("--jobs", type=int, default=12)
    p.add_argument("--ticks", type=int, default=16)
    p.add_argument("--window", type=int, default=8)
    p.add_argument("--ranks", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--delay-ms", type=float, default=150.0)
    p.add_argument("--fault-every", type=int, default=3,
                   help="every K-th job gets an injected fault (0 = none)")
    p.add_argument("--fabric", action="store_true",
                   help="with --synth: emit per-rank switch/pod fabric "
                        "placement on every arrive/resize row (ships as "
                        "SFP2-v3 topology sections)")
    p.add_argument("--shared-switch", action="store_true",
                   help="with --synth: tier-attribution trace — the "
                        "faulted ranks land on distinct hosts under ONE "
                        "shared switch with concurrent data stalls "
                        "(implies --fabric; pair with --incidents to see "
                        "the switch-tier fleet incident)")
    p.add_argument("--save-trace", default="",
                   help="with --synth: also write the generated trace here")
    p.add_argument("--out", default="",
                   help="also write the report JSON to this path")
    return p


def run(args) -> dict:
    if args.trace:
        trace = load_trace(args.trace)
    else:
        text = generate_trace(
            jobs=args.jobs, ticks=args.ticks, window_steps=args.window,
            world_size=args.ranks, seed=args.seed, delay_ms=args.delay_ms,
            fault_every=args.fault_every, fabric=args.fabric,
            shared_switch=args.shared_switch,
        )
        if args.save_trace:
            with open(args.save_trace, "w") as f:
                f.write(text)
        trace = parse_trace(text, name=f"synth-{args.seed}")
    report = replay_trace(
        trace, wire=args.wire, compress=args.compress, top_k=args.top_k,
        evict_after=args.evict_after, incidents=args.incidents,
        fused=args.tick_path == "fused",
        shards=args.shards, shard_workers=args.shard_workers,
        obs=args.obs,
    )
    out = report.as_dict()
    out["wire"] = args.wire
    out["compress"] = args.compress
    out["tick_path"] = args.tick_path
    out["shards"] = args.shards or 0
    return out


def main() -> None:
    args = make_argparser().parse_args()
    out = run(args)
    text = json.dumps(out, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)
    # a trace whose rows ALL failed to parse is an operator error even
    # though per-row damage is tolerated: exit non-zero so scripts notice
    if out["loader"]["rows"] and not out["loader"]["accepted"]:
        sys.exit(3)


if __name__ == "__main__":
    main()
