"""Batched serving driver: prefill + token-by-token decode with monitoring.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch paper-gpt-125m --reduced --batch 4 --prompt-len 32 --decode 32

Serving taxonomy: request.wait / prefill / decode.dispatch /
decode.device_wait / callbacks / residual — the same ordered-stage contract
(schemas are data, not code).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.contract import StageSchema
from ..distributed.sharding import DECODE_PLAN
from ..models import build_model
from ..telemetry.collector import Monitor
from .mesh import make_local_mesh
from .steps import build_serve_step

SERVE_STAGES = (
    "request.wait",
    "prefill.cpu_wall",
    "decode.dispatch_cpu_wall",
    "decode.device_wait_cpu_wall",
    "callbacks.cpu_wall",
    "step.other_cpu_wall",
)


def make_argparser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="paper-gpt-125m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--decode", type=int, default=32)
    p.add_argument("--window", type=int, default=16)
    return p


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_local_mesh()
    seq_len = args.prompt_len + args.decode
    schema = StageSchema(SERVE_STAGES, world_size=1)
    monitor = Monitor(schema, window_steps=args.window, event_q=0.0)

    rng = jax.random.PRNGKey(0)
    with mesh:
        params = model.init(rng)
        serve_step, _ = build_serve_step(model, mesh, DECODE_PLAN, seq_len)
        prompts = jax.random.randint(
            rng, (args.batch, args.prompt_len), 0, cfg.vocab_size
        )
        tokens_out = []
        t0 = time.perf_counter()
        with monitor.step():
            with monitor.stage("request.wait"):
                pass  # synthetic batched request already materialized
            with monitor.stage("prefill.cpu_wall"):
                if cfg.family == "encdec":
                    frames = jnp.zeros(
                        (args.batch, max(seq_len // cfg.enc_seq_divisor, 1), cfg.d_model),
                        jnp.dtype(cfg.compute_dtype),
                    )
                    caches = model.init_caches(params, args.batch, seq_len, frames=frames)
                else:
                    caches = model.init_caches(params, args.batch, seq_len)
                # feed the prompt token-by-token (cache warmup)
                for i in range(args.prompt_len):
                    logits, caches = serve_step(
                        params, caches, prompts[:, i : i + 1], jnp.int32(i)
                    )
        monitor.end_of_step()
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        for j in range(args.decode):
            with monitor.step():
                with monitor.stage("decode.dispatch_cpu_wall"):
                    logits, caches = serve_step(
                        params, caches, tok, jnp.int32(args.prompt_len + j)
                    )
                with monitor.stage("decode.device_wait_cpu_wall"):
                    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
                    tok.block_until_ready()
                with monitor.stage("callbacks.cpu_wall"):
                    tokens_out.append(np.asarray(tok[:, 0]))
            monitor.end_of_step()
        elapsed = time.perf_counter() - t0

    # the final partial window stays buffered inside the Monitor (only
    # full windows are gathered), so flush() alone would drop the labels
    # of the last window that actually closed — fall back to it.
    report = monitor.aggregator.flush() or monitor.aggregator.last_report()
    return {
        "arch": cfg.name,
        "batch": args.batch,
        "decoded": len(tokens_out),
        "tokens_per_second": args.batch * len(tokens_out) / elapsed,
        "last_window_labels": list(report.diagnosis.labels) if report else [],
        "last_window_routing": list(report.diagnosis.routing_stages) if report else [],
        "sample_output": [int(t[0]) for t in tokens_out[:8]],
    }


def main() -> None:
    args = make_argparser().parse_args()
    print(json.dumps(run(args), indent=2))


if __name__ == "__main__":
    main()
