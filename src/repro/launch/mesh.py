"""Mesh construction (functions only — importing never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import).

Production target: TPU v5e-class pods of 256 chips, 16x16 per pod; the
multi-pod mesh adds a leading `pod` axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax
import numpy as np

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_fleet_mesh",
    "HARDWARE",
]

#: roofline constants (TPU v5e-class), used by repro.analysis.roofline.
HARDWARE = {
    "peak_bf16_flops": 197e12,   # per chip
    "hbm_bandwidth": 819e9,      # bytes/s per chip
    "ici_link_bandwidth": 50e9,  # bytes/s per link
}


def _axis_type_kwargs(n_axes: int) -> dict:
    """`axis_types=Auto` where supported; jax < 0.5 predates AxisType."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist (CPU smoke / single-host runs)."""
    n = len(jax.devices())
    if data is None:
        data = max(1, n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kwargs(2)
    )


def make_fleet_mesh(shards: int | None = None):
    """1-D ``shard`` mesh for the sharded fleet service.

    One mesh slot per worker shard, over the host's devices: on CPU,
    set ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    the first jax import to expose N devices in one process (the
    N-shard CPU test rig; see `fleet.shard.ShardedFleetService`).  When
    fewer devices exist than `shards`, the mesh is built over what
    exists and `distributed.sharding.shard_placements` round-robins the
    shards onto it.
    """
    devs = jax.devices()
    n = len(devs) if shards is None else max(1, min(int(shards), len(devs)))
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("shard",))
