"""Sharded train / prefill / serve step builders.

`build_*` functions return (jitted_fn, in_shardings, out_shardings) wired
from the logical-axis rules of the model and a ShardingPlan — the same
builders serve the live trainer, the serving loop, and the multi-pod
dry-run (which lowers them against ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import (
    ShardingPlan,
    batch_sharding,
    cache_sharding,
    ssm_cache_sharding,
    tree_shardings,
)
from ..models.model_zoo import Model
from ..optim.adamw import AdamWConfig, OptState, apply_updates, init_opt

__all__ = [
    "TrainState",
    "build_train_step",
    "build_prefill_step",
    "build_serve_step",
    "batch_shardings_for",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState
    step: jax.Array


def batch_shardings_for(model: Model, mesh: Mesh, plan: ShardingPlan, specs: dict):
    out = {}
    for name, spec in specs.items():
        out[name] = batch_sharding(mesh, len(spec.shape), plan)
    return out


_ATTN_CACHE_KEYS = {"k", "v", "cross_k", "cross_v"}


def cache_shardings_for(mesh: Mesh, plan: ShardingPlan, cache_specs: Any,
                        seq_dim: int = 2):
    """Attention caches [L,B,S,KV,D] shard batch+cache-seq; SSM state and
    conv-tail caches [L,B,...] shard batch only (identified by key name —
    the conv tail is 4-D but its dim 2 is the conv window, not sequence).
    Cache-seq sharding is dropped when the cache length doesn't divide the
    axis (sliding-window ring buffers)."""
    from jax.sharding import PartitionSpec as PS

    def leaf(path, s):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in _ATTN_CACHE_KEYS:
            sh = cache_sharding(mesh, s.shape, plan, seq_dim=seq_dim)
            # sanitize: uneven cache-seq or batch dims fall back to replicated
            dims = list(sh.spec) + [None] * (len(s.shape) - len(sh.spec))
            for i, (dim, size) in enumerate(zip(dims, s.shape)):
                if dim is None:
                    continue
                axes_i = (dim,) if isinstance(dim, str) else dim
                prod = 1
                for a in axes_i:
                    prod *= mesh.shape[a]
                if size % prod != 0:
                    dims[i] = None
            return NamedSharding(mesh, PS(*dims))
        sh = ssm_cache_sharding(mesh, s.shape, plan)
        dims = list(sh.spec) + [None] * (len(s.shape) - len(sh.spec))
        for i, (dim, size) in enumerate(zip(dims, s.shape)):
            if dim is None:
                continue
            axes_i = (dim,) if isinstance(dim, str) else dim
            prod = 1
            for a in axes_i:
                prod *= mesh.shape[a]
            if size % prod != 0:
                dims[i] = None
        return NamedSharding(mesh, PS(*dims))

    return jax.tree_util.tree_map_with_path(leaf, cache_specs)


def build_train_step(
    model: Model,
    mesh: Mesh,
    plan: ShardingPlan,
    opt_cfg: AdamWConfig | None = None,
    *,
    batch_specs: dict | None = None,
    accum_steps: int = 1,
    triangular: bool = False,
    donate: bool = True,
    zero1: bool = True,
):
    """Fused train step: grads -> clip -> AdamW, optional microbatch accum.

    zero1=True shards AdamW mu/nu over the `data` axis (ZeRO-1): GSPMD
    reduce-scatters grads into the sharded update and all-gathers the new
    params, replacing the replicated-state grad all-reduce.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    param_sh = tree_shardings(mesh, model.param_axes(), plan, params_spec)
    if zero1 and "data" in mesh.axis_names:
        dsize = mesh.shape["data"]

        def opt_leaf(sh: NamedSharding, spec_leaf) -> NamedSharding:
            dims = list(sh.spec) + [None] * (len(spec_leaf.shape) - len(sh.spec))
            used = {
                a
                for dim in dims
                for a in ((dim,) if isinstance(dim, str) else (dim or ()))
            }
            if "data" in used:
                return sh
            for i, (dim, size) in enumerate(zip(dims, spec_leaf.shape)):
                if dim is None and size % dsize == 0 and size >= dsize:
                    dims[i] = "data"
                    return NamedSharding(sh.mesh, P(*dims))
            return sh

        opt_sh = jax.tree.map(opt_leaf, param_sh, params_spec)
    else:
        opt_sh = param_sh
    state_sh = TrainState(
        params=param_sh,
        opt=OptState(mu=opt_sh, nu=opt_sh, count=NamedSharding(mesh, P())),
        step=NamedSharding(mesh, P()),
    )

    def loss_fn(params, batch):
        return model.loss(params, batch, triangular=triangular)

    def train_step(state: TrainState, batch: dict):
        if accum_steps > 1:
            # batch arrives HOST-SHAPED as [accum, micro, ...] with the
            # micro dim data-sharded: reshaping a sharded batch dim on
            # device confuses GSPMD into replicating the microbatch.
            def micro(c, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                acc_loss, acc_grads = c
                return (
                    acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads),
                ), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zeros), batch
            )
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        params, opt, om = apply_updates(opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **om}
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    batch_sh = None
    if batch_specs:
        if accum_steps > 1:
            # [accum, micro, ...] layout: leading accum dim replicated,
            # micro batch dim sharded over the DP axes.
            batch_sh = {
                name: NamedSharding(
                    mesh,
                    P(None, *batch_sharding(mesh, len(spec.shape), plan).spec),
                )
                for name, spec in batch_specs.items()
            }
        else:
            batch_sh = batch_shardings_for(model, mesh, plan, batch_specs)
    fn = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return fn, state_sh


def build_prefill_step(
    model: Model,
    mesh: Mesh,
    plan: ShardingPlan,
    *,
    batch_specs: dict | None = None,
    triangular: bool = False,
):
    params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    param_sh = tree_shardings(mesh, model.param_axes(), plan, params_spec)

    def prefill(params, batch):
        return model.forward(params, batch, triangular=triangular)

    batch_sh = (
        batch_shardings_for(model, mesh, plan, batch_specs) if batch_specs else None
    )
    fn = jax.jit(
        prefill,
        in_shardings=(param_sh, batch_sh),
        out_shardings=None,
    )
    return fn, param_sh


def build_serve_step(
    model: Model,
    mesh: Mesh,
    plan: ShardingPlan,
    seq_len: int,
    *,
    cache_specs: Any = None,
    token_batch: int | None = None,
):
    """One decode token against the KV/state caches (donated)."""
    params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    param_sh = tree_shardings(mesh, model.param_axes(), plan, params_spec)

    def serve(params, caches, tokens, index):
        logits, new_caches = model.decode_step(params, caches, tokens, index, seq_len)
        return logits, new_caches

    cache_sh = (
        cache_shardings_for(
            mesh, plan, cache_specs,
            seq_dim=3 if model.cfg.cache_layout == "bksd" else 2,
        )
        if cache_specs is not None
        else None
    )
    tok_sh = (
        batch_sharding(mesh, 2, plan) if token_batch is not None else None
    )
    fn = jax.jit(
        serve,
        in_shardings=(param_sh, cache_sh, tok_sh, NamedSharding(mesh, P())),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    return fn, param_sh


def init_train_state(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt(params), step=jnp.zeros((), jnp.int32))
