import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production meshes and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out experiments/dryrun

The two lines above MUST precede any other import (jax locks the device
count at first init); this is the only entry point that forces 512 host
devices.

Per live cell this produces:
  - production-graph compile (scan-over-layers) -> memory_analysis proves
    the per-device fit; collective schedule from the compiled HLO;
  - unrolled-delta cost extraction (DESIGN.md §6): the same step lowered
    with 1 and 2 unrolled layers, extrapolated to L — exact per-step HLO
    FLOPs / bytes / collective bytes despite scan bodies being counted
    once by XLA's cost analysis;
  - the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..analysis.roofline import CellCosts, model_flops, roofline
from ..configs import ARCHITECTURES, ASSIGNED, SHAPES, get_config, shape_applicable
from ..distributed.sharding import (
    BASELINE_PLAN,
    DECODE_PLAN,
    DP_ALL_PLAN,
    DP_FSDP_PLAN,
    ShardingPlan,
)
from ..models import build_model
from ..optim.adamw import AdamWConfig
from .mesh import make_production_mesh
from .steps import (
    build_prefill_step,
    build_serve_step,
    build_train_step,
    init_train_state,
)

PLANS = {
    "baseline": BASELINE_PLAN,
    "decode": DECODE_PLAN,
    "dp_all": DP_ALL_PLAN,
    "dp_fsdp": DP_FSDP_PLAN,
}


def _batch_axes_for(shape, mesh, plan) -> tuple[str, ...]:
    """Shard batch over as many DP axes as divide it (B=1 -> replicated)."""
    axes = []
    b = shape.global_batch
    for ax in plan.batch_axes:
        if ax in mesh.axis_names and b % mesh.shape[ax] == 0 and mesh.shape[ax] > 1:
            axes.append(ax)
            b //= mesh.shape[ax]
    return tuple(axes)


def _plan_for(cfg, shape, mesh, plan: ShardingPlan) -> ShardingPlan:
    rules = dict(plan.rules)
    model_size = mesh.shape.get("model", 1)
    # GQA-aware TP: replicate KV projections when the KV head count does not
    # divide the TP degree (padding churn costs more than the tiny KV GEMM).
    if cfg.n_kv_heads and cfg.n_kv_heads % model_size != 0:
        rules["kv_heads"] = None
    return dataclasses.replace(
        plan, rules=rules, batch_axes=_batch_axes_for(shape, mesh, plan)
    )


#: train cells run with microbatch accumulation so activations fit HBM
#: (global batch 256 -> 4 microbatches of 64); part of the recorded baseline.
TRAIN_ACCUM = 4


def lower_cell(
    cfg, shape, mesh, plan: ShardingPlan, *,
    triangular: bool = False, accum: int | None = None, zero1: bool = True,
):
    """Lower + compile the production (scan) graph for one cell."""
    model = build_model(cfg)
    plan = _plan_for(cfg, shape, mesh, plan)
    specs = model.input_specs(shape)
    with mesh:
        if shape.kind == "train":
            accum_steps = TRAIN_ACCUM if accum is None else accum
            if accum_steps > 1:
                # host-side [accum, micro, ...] layout (see steps.py)
                specs = {
                    k: jax.ShapeDtypeStruct(
                        (accum_steps, s.shape[0] // accum_steps) + s.shape[1:],
                        s.dtype,
                    )
                    for k, s in specs.items()
                }
            step, state_sh = build_train_step(
                model, mesh, plan, AdamWConfig(),
                batch_specs=model.input_specs(shape),
                triangular=triangular,
                accum_steps=accum_steps,
                zero1=zero1,
            )
            state_spec = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0))
            )
            lowered = step.lower(state_spec, specs)
        elif shape.kind == "prefill":
            step, _ = build_prefill_step(
                model, mesh, plan, batch_specs=specs, triangular=triangular
            )
            params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            lowered = step.lower(params_spec, specs)
        else:  # decode
            cache_specs = model.cache_specs(shape)
            step, _ = build_serve_step(
                model, mesh, plan, shape.seq_len,
                cache_specs=cache_specs, token_batch=shape.global_batch,
            )
            params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            lowered = step.lower(
                params_spec, cache_specs, specs["tokens"], jax.ShapeDtypeStruct((), jnp.int32)
            )
        compiled = lowered.compile()
    return compiled


def moe_layer_costs(cfg, shape, mesh, plan) -> "CellCosts":
    """Standalone per-layer MoE cost at PRODUCTION group size.

    MoE cost is linear in tokens at fixed group size (dispatch per token =
    topk*cf*g*D; expert/router per token fixed), so we lower apply_moe on a
    small unrolled token count (4 groups) and scale to the cell's tokens.
    For train shapes the lowering includes the backward (value_and_grad).
    """
    from ..models import moe as moe_lib
    from ..distributed.sharding import sharding_for_axes

    g = cfg.moe_group
    t_small = 4 * g
    t_full = shape.global_batch * shape.seq_len
    mcfg = dataclasses.replace(cfg, unroll_inner=True)
    dtype = jnp.dtype(cfg.compute_dtype)
    p_specs = jax.eval_shape(
        lambda: moe_lib.init_moe(jax.random.PRNGKey(0), mcfg, dtype)
    )
    # batch dim sized to the DP sharding (as in the real model); the group
    # structure operates on the flattened token count either way.
    b_eff = 1
    for ax in plan.batch_axes:
        b_eff *= mesh.shape.get(ax, 1)
    b_eff = max(b_eff, 1)
    x_spec = jax.ShapeDtypeStruct(
        (b_eff, max(t_small // b_eff, 1), cfg.d_model), dtype
    )
    t_small = x_spec.shape[0] * x_spec.shape[1]
    axes = moe_lib.moe_axes()
    p_sh = {
        k: sharding_for_axes(mesh, axes[k], plan) for k in p_specs
    }
    from .steps import batch_sharding as _bs

    def fwd(p, x):
        y, aux = moe_lib.apply_moe(p, x, mcfg)
        return (y.astype(jnp.float32) ** 2).sum() + aux

    fn = jax.grad(fwd) if shape.kind == "train" else fwd
    with mesh:
        compiled = jax.jit(
            fn, in_shardings=(p_sh, _bs(mesh, 3, plan))
        ).lower(p_specs, x_spec).compile()
    c = CellCosts.from_compiled(compiled)
    scale = t_full / t_small
    if shape.kind == "train":
        scale *= 6.0 / 4.0  # grad-of-fwd ~ 4x fwd; a train step ~ 6x fwd
    return CellCosts(
        flops=c.flops * scale,
        bytes_accessed=c.bytes_accessed * scale,
        coll_bytes=c.coll_bytes * scale,
        coll_by_kind={k: v * scale for k, v in c.coll_by_kind.items()},
        coll_counts=c.coll_counts,
    )


def unrolled_delta_costs(
    cfg, shape, mesh, plan, *,
    triangular: bool = False, accum: int | None = None, zero1: bool = True,
):
    """Lower 1- and 2-layer unrolled variants; extrapolate to cfg.n_layers.

    MoE blocks are removed from the trunk here (their group loop at full
    token count cannot be unrolled at sane compile cost) and added back via
    the standalone linear-in-tokens measurement of `moe_layer_costs`.
    """
    is_moe = cfg.n_experts > 0

    def with_layers(l):
        enc = min(cfg.n_enc_layers, l) if cfg.n_enc_layers else 0
        # unroll_inner: attention-chunk / SSD-chunk loops are python-
        # unrolled with identical math so every iteration is counted
        # (XLA cost analysis counts a while body once).  Masked-full
        # attention cost is chunking-invariant, so the unrolled variants
        # use 8k chunks (16 blocks at 32k seq instead of 1024 -- compile
        # time).  Triangular keeps production chunks: its skipped-pair
        # ratio depends on chunk granularity.
        qc, kc = cfg.attn_q_chunk, cfg.attn_kv_chunk
        if not triangular:
            qc, kc = max(qc, 8192), max(kc, 8192)
        return dataclasses.replace(
            cfg, n_layers=l, n_enc_layers=enc, scan_layers=False,
            unroll_inner=True, attn_q_chunk=qc, attn_kv_chunk=kc,
            n_experts=0 if is_moe else cfg.n_experts,
            top_k=0 if is_moe else cfg.top_k,
        )

    # accum=1 here: the microbatch loop is a scan whose body cost analysis
    # would count once; per-step totals are identical at accum=1 (the grad
    # reduction happens once per step either way), so the delta variants
    # lower the unaccumulated step.
    c1 = CellCosts.from_compiled(
        lower_cell(with_layers(1), shape, mesh, plan,
                   triangular=triangular, accum=1, zero1=zero1)
    )
    c2 = CellCosts.from_compiled(
        lower_cell(with_layers(2), shape, mesh, plan,
                   triangular=triangular, accum=1, zero1=zero1)
    )
    # encoder layers extrapolate with the decoder factor (equal counts for
    # the assigned enc-dec arch: 6/6)
    costs = c1.delta_extrapolate(c2, cfg.n_layers)
    if is_moe and shape.kind != "decode":
        mc = moe_layer_costs(cfg, shape, mesh, plan)
        kinds = set(costs.coll_by_kind) | set(mc.coll_by_kind)
        costs = CellCosts(
            flops=costs.flops + cfg.n_layers * mc.flops,
            bytes_accessed=costs.bytes_accessed + cfg.n_layers * mc.bytes_accessed,
            coll_bytes=costs.coll_bytes + cfg.n_layers * mc.coll_bytes,
            coll_by_kind={
                k: costs.coll_by_kind.get(k, 0.0)
                + cfg.n_layers * mc.coll_by_kind.get(k, 0.0)
                for k in kinds
            },
            coll_counts=costs.coll_counts,
        )
    elif is_moe:
        # decode: 128 tokens = a single group; unrolling is free, so lower
        # the delta WITH the MoE blocks intact.
        def with_layers_moe(l):
            return dataclasses.replace(
                cfg, n_layers=l, scan_layers=False, unroll_inner=True
            )

        c1m = CellCosts.from_compiled(
            lower_cell(with_layers_moe(1), shape, mesh, plan,
                       triangular=triangular, accum=1, zero1=zero1)
        )
        c2m = CellCosts.from_compiled(
            lower_cell(with_layers_moe(2), shape, mesh, plan,
                       triangular=triangular, accum=1, zero1=zero1)
        )
        costs = c1m.delta_extrapolate(c2m, cfg.n_layers)
    return costs


def run_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    *,
    plan_name: str = "",
    triangular: bool = False,
    skip_production: bool = False,
    accum: int | None = None,
    zero1: bool = True,
    attn_bf16: bool = False,
    attn_remat: bool = True,
    cache_bksd: bool = False,
    moe_wgather: bool = False,
) -> dict:
    cfg = get_config(arch)
    if moe_wgather:
        cfg = dataclasses.replace(cfg, moe_weight_gather=True)
    if attn_bf16:
        cfg = dataclasses.replace(cfg, attn_cast_f32=False)
    if not attn_remat:
        cfg = dataclasses.replace(cfg, attn_remat=False)
    if cache_bksd:
        cfg = dataclasses.replace(cfg, cache_layout="bksd")
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": reason,
        }
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size
    base_plan = PLANS[plan_name or ("decode" if shape.kind == "decode" else "baseline")]

    out: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips, "plan": base_plan.name, "status": "ok",
        "triangular": triangular,
        "accum": (TRAIN_ACCUM if accum is None else accum) if shape.kind == "train" else 1,
        "zero1": zero1,
        "attn_bf16": attn_bf16,
    }
    t0 = time.time()
    if not skip_production:
        compiled = lower_cell(cfg, shape, mesh, base_plan,
                              triangular=triangular, accum=accum, zero1=zero1)
        ma = compiled.memory_analysis()
        out["compile_s"] = round(time.time() - t0, 2)
        out["memory"] = {
            "args_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "total_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3
            ),
        }
        scan_costs = CellCosts.from_compiled(compiled)
        out["scan_graph_costs"] = dataclasses.asdict(scan_costs)
        del compiled

    t1 = time.time()
    costs = unrolled_delta_costs(cfg, shape, mesh, base_plan,
                                 triangular=triangular, accum=accum, zero1=zero1)
    out["delta_s"] = round(time.time() - t1, 2)
    mf = model_flops(cfg, shape)
    rl = roofline(costs, n_chips, mf)
    out["costs"] = dataclasses.asdict(costs)
    out["roofline"] = rl.as_dict()
    return out


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--plan", default="", help="override sharding plan")
    p.add_argument("--triangular", action="store_true")
    p.add_argument("--skip-production", action="store_true",
                   help="delta costs only (no full scan-graph compile)")
    p.add_argument("--accum", type=int, default=-1,
                   help="train microbatch accumulation (-1 = default)")
    p.add_argument("--no-zero1", action="store_true")
    p.add_argument("--attn-bf16", action="store_true",
                   help="bf16 attention operands with f32 accumulation")
    p.add_argument("--no-attn-remat", action="store_true",
                   help="save q-block residuals instead of recomputing")
    p.add_argument("--cache-bksd", action="store_true",
                   help="head-major decode cache layout [B,KV,S,D]")
    p.add_argument("--moe-wgather", action="store_true",
                   help="gather expert weights over data at use")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--tag", default="")
    args = p.parse_args()

    archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{args.tag}_" if args.tag else ""
                path = os.path.join(
                    args.out, f"{tag}{mesh_name}__{arch}__{shape_name}.json"
                )
                t0 = time.time()
                try:
                    row = run_cell(
                        arch, shape_name, mesh_name,
                        plan_name=args.plan, triangular=args.triangular,
                        skip_production=args.skip_production,
                        accum=None if args.accum < 0 else args.accum,
                        zero1=not args.no_zero1,
                        attn_bf16=args.attn_bf16,
                        attn_remat=not args.no_attn_remat,
                        cache_bksd=args.cache_bksd,
                        moe_wgather=args.moe_wgather,
                    )
                except Exception as e:
                    failures += 1
                    row = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                row["wall_s"] = round(time.time() - t0, 2)
                with open(path, "w") as f:
                    json.dump(row, f, indent=1)
                status = row["status"]
                extra = ""
                if status == "ok" and "roofline" in row:
                    r = row["roofline"]
                    extra = (
                        f" dom={r['dominant']} c={r['compute_s']:.2e}"
                        f" m={r['memory_s']:.2e} x={r['collective_s']:.2e}"
                        f" useful={r['useful_ratio']:.2f}"
                    )
                print(f"[{mesh_name}] {arch} x {shape_name}: {status}{extra} ({row['wall_s']}s)", flush=True)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
