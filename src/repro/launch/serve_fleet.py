"""Fleet aggregation serving driver: many jobs -> one routing answer.

    PYTHONPATH=src python -m repro.launch.serve_fleet \
        --jobs 12 --ranks 8 --window 20 --rounds 4 --top-k 3

Simulates a heterogeneous fleet (DDP / FSDP / ZeRO-1 sync profiles, E3
fault families on a subset of jobs, one job that dies, one whose gather
degrades), runs each job's windows through the standard WindowAggregator,
ships the resulting evidence packets over the int8 wire format, and drives
a `FleetService`: ingest -> tick/evict -> batched kernel refresh (frontier
+ counterfactual what-if) -> top-K persistence-weighted recoverable-time
routing.  Prints a JSON summary (the serving response shape): each
routing entry carries the estimated recoverable seconds a fix at its
(stage, rank) is worth, plus the fault's temporal regime
(transient/recurring/persistent), persistence weight and onset step.

With `--topology private|shared|fabric` the packets additionally
declare each job's rank->host placement (SFP2-v2 host section; `fabric`
adds the per-rank switch/pod tiers as SFP2-v3 sections) and the
incident tier runs on top: the summary gains a durable `incidents`
table (lifecycle, exposure since onset, fleet-level common-cause
incidents promoted to the narrowest explaining tier — `shared` yields a
host incident, `fabric` a switch incident on the shared uplink) and an
`escalations` list (the budgeted profiler-attachment plan; at most
`--budget` per tick).  `--max-windows` bounds each job's retained
temporal history (memory knob for very long runs).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from ..core import WindowAggregator
from ..fleet import FleetService, ShardedFleetService
from ..incidents import EscalationController, IncidentEngine
from ..sim import ClusterSpec, simulate
from ..sim.scenarios import (
    DDP_SYNC,
    E3_FAMILIES,
    FSDP_SYNC,
    ZERO1_SYNC,
    ddp_scenario,
    hidden_fault_rank,
    hidden_rank_scenario,
)
from ..telemetry.packets import encode_packet, from_diagnosis

SYNC_PROFILES = {
    "ddp": DDP_SYNC,
    "fsdp": FSDP_SYNC,
    "zero1": ZERO1_SYNC,
}

#: host name shared by every faulted job's faulted rank under
#: --topology shared (the injected common cause).
SHARED_HOST = "shared-0"

#: fabric nodes shared by every faulted job's faulted rank under
#: --topology fabric: each faulted rank keeps its own PRIVATE host, but
#: all those hosts hang under one switch (the oversubscribed-uplink
#: shape) — the incident engine must promote ONE switch-tier incident.
SHARED_SWITCH = "fab-sw0"
SHARED_POD = "fab-pod0"


def make_argparser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=12)
    p.add_argument("--ranks", type=int, default=8)
    p.add_argument("--window", type=int, default=20)
    p.add_argument("--rounds", type=int, default=4)
    p.add_argument("--top-k", type=int, default=3)
    p.add_argument("--delay-ms", type=float, default=150.0)
    p.add_argument("--fault-every", type=int, default=3,
                   help="every K-th job gets an injected E3 fault")
    p.add_argument("--compress", default="int8",
                   choices=["none", "int8", "int8.delta"])
    p.add_argument("--wire", default="sfp2", choices=["sfp1", "sfp2"],
                   help="wire framing (sfp1 = legacy back-compat route; "
                        "int8.delta requires sfp2)")
    p.add_argument("--topology", default="none",
                   choices=["none", "private", "shared", "fabric"],
                   help="declare per-job host placement in the packets "
                        "(SFP2-v2 host section) and run the incident "
                        "tier: 'private' packs 2 ranks/host per job; "
                        "'shared' additionally re-homes every faulted "
                        "job's faulted rank onto one fleet-shared host "
                        "(and pins faulted jobs to the 'data' family, "
                        "so the common cause is a single host+stage "
                        "the incident engine must promote); 'fabric' "
                        "keeps each faulted rank on its own host but "
                        "hangs all those hosts under one shared switch "
                        "(per-rank switch/pod SFP2-v3 sections) — the "
                        "engine must promote ONE switch-tier incident, "
                        "never per-host duplicates")
    p.add_argument("--budget", type=int, default=2,
                   help="profiler escalations per tick "
                        "(EscalationController token budget)")
    p.add_argument("--max-windows", type=int, default=None,
                   help="bound per-job temporal history: the registry "
                        "retains at most this many windows of regime "
                        "state per job (pass-through to FleetRegistry "
                        "regime_windows; default 4).  The knob that "
                        "bounds memory on very long runs")
    p.add_argument("--shards", type=int, default=None,
                   help="serve through a ShardedFleetService with this "
                        "many worker shards (stable job-id hash "
                        "partition; answers are bit-identical to the "
                        "default single-process service).  On CPU, set "
                        "XLA_FLAGS=--xla_force_host_platform_device_"
                        "count=N before launch to give each shard its "
                        "own device")
    p.add_argument("--shard-workers", default="thread",
                   choices=["thread", "inline"],
                   help="per-shard execution lanes under --shards: "
                        "'thread' overlaps wire decode with kernel "
                        "dispatch across shards; 'inline' runs shards "
                        "sequentially (deterministic debugging "
                        "reference — same outputs, only wall-clock "
                        "differs)")
    p.add_argument("--obs", default=True,
                   action=argparse.BooleanOptionalAction,
                   help="self-observability (repro.obs): tick-phase "
                        "frontier over the service's own pipeline, "
                        "metrics registry, flight recorder — surfaced "
                        "as a top-level 'obs' section in the JSON "
                        "summary (docs/observability.md).  On by "
                        "default (<1%% overhead, gated by "
                        "benchmarks/obs_overhead.py); --no-obs is the "
                        "benchmark control arm")
    return p


def _cluster_for(args, j: int, faulted: bool) -> ClusterSpec | None:
    """Per-job placement under --topology (None when undeclared)."""
    if args.topology == "none":
        return None
    hosts = list(
        ClusterSpec.uniform(args.ranks, 2, prefix=f"h{j}").hosts
    )
    if args.topology == "shared" and faulted:
        # the faulted rank of every faulted job sits on ONE shared host:
        # the injected common cause the incident tier must promote
        hosts[hidden_fault_rank(j, args.ranks)] = SHARED_HOST
    if args.topology != "fabric":
        return ClusterSpec(world_size=args.ranks, hosts=tuple(hosts))
    # fabric: private switch+pod per host, then the shared uplink over
    # the faulted rank's (still private) host — no host is shared, so
    # the narrowest explaining tier is the switch.
    switches = [f"{h}.sw" for h in hosts]
    pods = [f"{h}.pod" for h in hosts]
    if faulted:
        # the switch is a HOST attribute: every rank of the faulted
        # rank's host must agree, else last-writer-wins re-homes the
        # host back onto its private uplink
        fault_host = hosts[hidden_fault_rank(j, args.ranks)]
        for r, h in enumerate(hosts):
            if h == fault_host:
                switches[r] = SHARED_SWITCH
                pods[r] = SHARED_POD
    return ClusterSpec(
        world_size=args.ranks, hosts=tuple(hosts),
        switches=tuple(switches), pods=tuple(pods),
    )


def _build_jobs(args) -> list[dict]:
    """Heterogeneous fleet: sync profile and fault family vary per job."""
    jobs = []
    steps = args.window * args.rounds
    profiles = list(SYNC_PROFILES.items())
    for j in range(args.jobs):
        profile_name, sync = profiles[j % len(profiles)]
        faulted = args.fault_every > 0 and j % args.fault_every == 0
        family = E3_FAMILIES[j % len(E3_FAMILIES)]
        if args.topology in ("shared", "fabric") and faulted:
            # a shared-node fault surfaces in the same stage in every
            # sharing job: pin the family (data.next_wait, non-sync in
            # every profile) so the common cause is promotable
            family = "data"
        cluster = _cluster_for(args, j, faulted)
        if faulted:
            sc = hidden_rank_scenario(
                family, world_size=args.ranks, steps=steps, seed=j,
                delay_ms=args.delay_ms, sync=sync,
            )
        else:
            sc = ddp_scenario(
                world_size=args.ranks, steps=steps, seed=j, sync=sync
            )
        if cluster is not None:
            sc = dataclasses.replace(sc, cluster=cluster)
        jobs.append({
            "job_id": f"job-{j:03d}-{profile_name}",
            "scenario": sc,
            "result": simulate(sc),
            "faulted": faulted,
            "family": family if faulted else "",
            "aggregator": WindowAggregator(sc.schema(), window_steps=args.window),
            # failure drama: job 1 dies after round 0; job 2's gather degrades
            "dies_after_round": 0 if j == 1 else None,
            "gather_degrades": j == 2,
        })
    return jobs


def run(args) -> dict:
    engine = (
        IncidentEngine() if args.topology != "none" else None
    )
    controller = (
        EscalationController(budget_per_tick=args.budget)
        if engine is not None
        else None
    )
    obs_on = getattr(args, "obs", True)
    if args.shards:
        service = ShardedFleetService(
            shards=args.shards, workers=args.shard_workers,
            window_capacity=args.window, evict_after=2, degrade_after=2,
            regime_windows=args.max_windows or 4,
            incidents=engine,
            obs=obs_on,
        )
    else:
        service = FleetService(
            window_capacity=args.window, evict_after=2, degrade_after=2,
            regime_windows=args.max_windows or 4,
            incidents=engine,
            obs=obs_on,
        )
    jobs = _build_jobs(args)
    packets_sent = 0
    bytes_sent = 0
    t0 = time.perf_counter()
    routes = []
    actions = []
    for w in range(args.rounds):
        batch: list[tuple[str, bytes]] = []
        for job in jobs:
            if job["dies_after_round"] is not None and w > job["dies_after_round"]:
                continue  # job stopped reporting: eviction path
            block = job["result"].durations[w * args.window:(w + 1) * args.window]
            gather_ok = not (job["gather_degrades"] and w >= 1)
            present = (
                tuple(r for r in range(args.ranks) if r != args.ranks - 1)
                if not gather_ok else tuple(range(args.ranks))
            )
            report = None
            for t in range(block.shape[0]):
                report = job["aggregator"].add_step(
                    block[t], block[t].sum(-1),
                    gather_ok=gather_ok, present_ranks=present,
                ) or report
            if report is None:
                continue
            pkt = from_diagnosis(
                report.diagnosis,
                job["scenario"].stages,
                report.steps,
                args.ranks,
                report.window_index,
                window=report.durations,
                present_ranks=present,
                sync_stages=job["scenario"].sync_stages,
                first_step=w * args.window,
                hosts=job["scenario"].hosts,
                switches=job["scenario"].switches,
                pods=job["scenario"].pods,
            )
            wire = encode_packet(pkt, compress=args.compress, wire=args.wire)
            batch.append((job["job_id"], wire))
            packets_sent += 1
            bytes_sent += len(wire)
        # one amortized decode+fold+kernel pass per aggregation round
        service.submit_many(batch, refresh=True)
        service.tick()
        routes = service.route(args.top_k)
        if controller is not None:
            actions.extend(
                controller.plan(service.current_tick, engine.incidents())
            )
    elapsed = time.perf_counter() - t0
    if args.shards:
        service.close()

    snapshot = service.snapshot()
    # the self-observability section is top-level in the summary (the
    # operator-facing "is the monitor itself slow" view,
    # docs/observability.md), not buried inside the snapshot
    obs_out = snapshot.pop("obs", None)
    out = {
        "jobs": args.jobs,
        "rounds": args.rounds,
        "shards": args.shards or 0,
        "wire": args.wire,
        "compress": args.compress,
        "packets_sent": packets_sent,
        "wire_bytes": bytes_sent,
        "wire_bytes_per_packet": bytes_sent // max(packets_sent, 1),
        "ingest_jobs_per_second": packets_sent / max(elapsed, 1e-9),
        "snapshot": snapshot,
        "routing": [
            {
                "job": r.job_id,
                "stage": r.stage,
                "rank": r.rank,
                "recoverable_s": round(r.recoverable_s, 4),
                "score": round(r.score, 4),
                "regime": r.regime,
                "persistence": round(r.persistence, 3),
                "onset_step": r.onset_step,
                "urgency": round(r.urgency, 3),
                "labels": list(r.labels),
            }
            for r in routes
        ],
    }
    if obs_out is not None:
        out["obs"] = obs_out
    if engine is not None:
        # durable incident view: identity + lifecycle over the same
        # evidence the stateless routing table above re-derives per tick
        out["incidents"] = engine.table()
        out["escalations"] = [
            {
                "tick": a.tick,
                "incident": a.incident_id,
                "jobs": list(a.jobs),
                "host": a.host,
                "stage": a.stage,
                "score": round(a.score, 4),
            }
            for a in actions
        ]
    return out


def main() -> None:
    args = make_argparser().parse_args()
    print(json.dumps(run(args), indent=2))


if __name__ == "__main__":
    main()
