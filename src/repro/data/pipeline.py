"""Deterministic synthetic token pipeline with background prefetch.

This is the substrate `data.next_wait` measures: batches are produced by a
worker thread into a bounded queue; `next()` blocks only when the consumer
outruns the producer (a data tail).  Determinism: batch t is a pure function
of (seed, shard, t), so restart-from-checkpoint resumes the exact stream by
cursor — the fault-tolerance contract for the data layer.

A `stall(step, seconds)` hook injects producer-side delays for the E3-style
live-loop experiments (the host-visible analogue of the paper's dataloader
faults).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

import numpy as np

__all__ = ["SyntheticTokens", "PrefetchPipeline"]


class SyntheticTokens:
    """Pure-function token batches: LCG-mixed, label = next-token shift.

    Tokens are power-law tilted (not uniform): a uniform stream has its
    cross-entropy floor at exactly log(V), leaving an untrained model zero
    headroom to improve; the tilt puts learnable unigram structure in the
    stream so short smoke trainings show a real loss decrease.
    """

    def __init__(
        self,
        vocab_size: int,
        batch: int,
        seq: int,
        *,
        seed: int = 0,
        shard: int = 0,
        num_shards: int = 1,
    ):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards

    def batch_at(self, cursor: int) -> dict[str, np.ndarray]:
        key = (
            self.seed * 0x9E3779B97F4A7C15
            + cursor * self.num_shards + self.shard + 1
        ) % (2**63)
        rng = np.random.default_rng(key)
        u = rng.random(size=(self.batch, self.seq + 1))
        tokens = np.minimum(
            (self.vocab_size * u**3).astype(np.int32), self.vocab_size - 1
        )
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class PrefetchPipeline:
    """Bounded-queue background prefetch over a batch source."""

    def __init__(
        self,
        source: SyntheticTokens,
        *,
        prefetch: int = 2,
        start_cursor: int = 0,
        stall: Callable[[int], float] | None = None,
    ):
        self.source = source
        self.cursor = start_cursor
        self._stall = stall or (lambda step: 0.0)
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._produced = start_cursor
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        while not self._stop.is_set():
            step = self._produced
            delay = self._stall(step)
            if delay > 0:
                time.sleep(delay)
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._produced += 1

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        step, batch = self._q.get()
        self.cursor = step + 1
        return batch

    def state(self) -> dict:
        """Checkpointable cursor (consumed count)."""
        return {"cursor": self.cursor, "seed": self.source.seed}

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
