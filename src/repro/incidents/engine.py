"""Incident engine: durable fault identity across windows, jobs, ticks.

The fleet service's `route(k)` is stateless — every window it re-derives
"where to aim the profiler" from scratch, so a persistent drift on one
host shared by three jobs surfaces as three unrelated, flickering route
entries, and nothing says *this is the same fault we flagged 40 windows
ago*.  This module is the missing layer between per-window evidence and
an operator console: it consumes route entries (recoverable seconds from
`core.whatif`, persistence/regime labels from `core.regimes`) and
maintains durable `Incident` objects with a full lifecycle:

    open -> active -> (merged) -> cooling -> resolved

  open      first sighting of a (job, stage, rank-set) candidate;
  active    the same candidate re-surfaced in a later tick or window —
            the fault has identity across windows now;
  merged    absorbed into a fleet-level common-cause incident (the
            member keeps accumulating exposure; the fleet incident
            represents it to the escalation tier);
  cooling   unseen for `cooling_after` ticks — maybe healed, kept warm
            so a flap re-attaches to the SAME incident instead of
            opening a duplicate;
  resolved  unseen through the cooling period ("healed"), or the job
            was evicted while the incident was live ("evicted"), or a
            fleet incident lost its quorum ("members_resolved").

Identity and dedup are deterministic: entries are folded in sorted
(job, stage, rank) order, an entry re-matching a live incident's
rank-set (or, with a declared `Topology`, a sibling rank on the same
host) folds into it, and exposure accumulates at most once per window
index — re-routing the same window every tick never double-counts.
Incident ids are derived from the matched key and opening tick, so any
permutation of one tick's submissions yields the identical incident set
(property-tested in ``tests/test_incident_properties.py``).

Cross-job correlation: given per-job activity series and a `Topology`,
the engine scores every topology tier whose nodes appear in >=
`min_jobs` jobs' incident streams (`tiered_co_activation_ref`, or the
batched Pallas route `kernels.frontier.tiered_co_activation` — ONE
dispatch over the concatenated host + switch + pod axes folding every
job's series) and promotes each co-activation set to the NARROWEST tier
that explains it: host candidates claim their member incidents first,
then switch candidates gather only still-unclaimed members, then pod
candidates — so three jobs sharing one faulted host are one host
incident, while three faulted hosts under one switch are ONE switch
incident, never three host incidents plus a duplicate switch view.
Fleet incidents outrank single-job entries in escalation, and wider
fabric tiers outrank narrower ones (`TIER_RANK`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

from .topology import TIERS, Topology

__all__ = [
    "ACTIVE",
    "COOLING",
    "CorrelationGroup",
    "Incident",
    "IncidentEngine",
    "IncidentParams",
    "LIVE_STATES",
    "MERGED",
    "OPEN",
    "RESOLVED",
    "TIER_RANK",
    "activity_meta",
    "fold_host_activity",
]

#: lifecycle states
OPEN = "open"
ACTIVE = "active"
MERGED = "merged"
COOLING = "cooling"
RESOLVED = "resolved"
LIVE_STATES = frozenset({OPEN, ACTIVE, MERGED, COOLING})

#: escalation precedence of the attribution tiers: a wider blast radius
#: outranks a narrower one (a pod incident explains more of the fleet
#: than a switch incident, which explains more than a host incident).
#: Job-scoped incidents carry the host tier.
TIER_RANK = {tier: rank for rank, tier in enumerate(TIERS)}


@dataclasses.dataclass(frozen=True)
class IncidentParams:
    """Thresholds of the incident lifecycle (all deterministic).

    min_recoverable_s: route entries priced at or below this never open
                       an incident (0.0 = any positive price does).
    cooling_after:     ticks unseen before a live incident cools.
    resolve_after:     further unseen ticks before a cooling incident
                       resolves as "healed".
    min_jobs:          distinct jobs required on one (host, stage) for
                       common-cause promotion.
    min_coactive_steps: steps with >= 2 jobs simultaneously active
                       required for promotion (separates a shared live
                       fault from disjoint coincidences).
    retention:         resolved incidents kept for operators (bounded
                       history; oldest pruned first).
    persistence_floor: score floor mirroring `FleetService` routing —
                       a healed incident keeps this fraction of its
                       exposure score.
    """

    min_recoverable_s: float = 0.0
    cooling_after: int = 2
    resolve_after: int = 4
    min_jobs: int = 2
    min_coactive_steps: int = 1
    retention: int = 256
    persistence_floor: float = 0.05


@dataclasses.dataclass(frozen=True)
class CorrelationGroup:
    """One stage-vocabulary cohort of the cross-job correlation.

    The unit of the cross-shard reduce: the coordinator derives groups
    from fleet-wide activity *metadata* (`IncidentEngine.correlation_plan`),
    every shard folds its own jobs' rank-level activity onto the group's
    candidate-host axis (`fold_host_activity` — the per-(host, stage)
    activity partials), and the coordinator stacks the partials in
    `job_ids` order and scores them with the co-activation kernel.  The
    single-process engine runs the exact same plan -> fold -> score
    pipeline over one local partial set, so sharded and unsharded
    promotion decisions are bit-identical by construction.

    The fabric tiers ride the SAME host-folded partials: the plan
    carries each candidate switch/pod axis plus the host-column ->
    node-column groupings (`tier_axes`), and the scoring side
    OR-collapses the stacked host partials onto them — nothing
    tier-shaped ever crosses a shard boundary, so the sharded reduce is
    tier-aware by construction and stays bit-identical to unsharded.
    """

    #: the group's shared stage vocabulary
    stages: tuple[str, ...]
    #: member job ids, sorted — the stacking order of the job axis
    job_ids: tuple[str, ...]
    #: aligned history depth: every member's most recent `n_steps` steps
    n_steps: int
    #: candidate host axis, sorted: hosts touched by a member job that
    #: sit under ANY candidate node (their own host tier, their switch,
    #: or their pod) — a host whose switch is shared by >= min_jobs
    #: members folds in even when the host itself is private to one job.
    hosts: tuple[str, ...]
    #: candidate switch axis (switches >= min_jobs members touch), sorted
    switches: tuple[str, ...] = ()
    #: per host column: index into `switches`, -1 = not a candidate
    switch_of: tuple[int, ...] = ()
    #: candidate pod axis (pods >= min_jobs members touch), sorted
    pods: tuple[str, ...] = ()
    #: per host column: index into `pods`, -1 = not a candidate
    pod_of: tuple[int, ...] = ()

    def tier_axes(self) -> list:
        """The fabric tiers as kernel `TierAxes` (empty axes dropped) —
        the aggregation maps `tiered_co_activation` scores over."""
        from ..kernels.frontier import TierAxes

        axes = []
        if self.switches:
            axes.append(
                TierAxes("switch", len(self.switches), self.switch_of)
            )
        if self.pods:
            axes.append(TierAxes("pod", len(self.pods), self.pod_of))
        return axes


def activity_meta(
    activity: Mapping[str, tuple[np.ndarray, tuple[str, ...]]],
) -> dict[str, tuple[int, tuple[str, ...]]]:
    """Correlation metadata of a per-job activity mapping: job id ->
    (usable step depth, stage vocabulary).

    Applies the engine's admission rules (3-D series, nonzero steps,
    stage axis matching the vocabulary) so a `correlation_plan` built
    from merged per-shard metadata sees exactly the jobs the
    single-process fold would."""
    meta: dict[str, tuple[int, tuple[str, ...]]] = {}
    for job_id in sorted(activity):
        act, stages = activity[job_id]
        act = np.asarray(act)
        if act.ndim != 3 or act.shape[0] == 0:
            continue
        if act.shape[2] != len(stages):
            continue
        meta[job_id] = (int(act.shape[0]), tuple(stages))
    return meta


def fold_host_activity(
    group: CorrelationGroup,
    activity: Mapping[str, tuple[np.ndarray, tuple[str, ...]]],
    topology: Topology,
) -> dict[str, np.ndarray]:
    """Fold rank-level activity onto `group`'s candidate-host axis.

    The shard-side half of the cross-shard reduce: for every group
    member present in `activity`, collapse its ``act[N, R, S]`` bool
    series over each host's ranks onto ``[n_steps, H_cand, S]`` (any
    rank of the host active => the host is active), aligned on the most
    recent `group.n_steps` steps.  Jobs outside the group (or absent
    from this shard's `activity`) are simply not emitted — the
    coordinator stacks partials from every shard in `group.job_ids`
    order.

    Fabric tiers need nothing extra here: switch/pod activity is
    derivable from these host partials (`group.tier_axes` OR-collapse,
    applied scoring-side), so the shard wire format is tier-agnostic
    and sharded tier promotion stays bit-identical to unsharded."""
    hcol = {h: i for i, h in enumerate(group.hosts)}
    out: dict[str, np.ndarray] = {}
    for job_id in group.job_ids:
        if job_id not in activity:
            continue
        act, _ = activity[job_id]
        act = np.asarray(act).astype(bool)
        job_hosts = topology.hosts_for(job_id)
        a_host = np.zeros(
            (group.n_steps, len(group.hosts), len(group.stages)), bool
        )
        tail = act[-group.n_steps:]
        for rank in range(min(act.shape[1], len(job_hosts))):
            col = hcol.get(job_hosts[rank])
            if col is not None:
                a_host[:, col, :] |= tail[:, rank, :]
        out[job_id] = a_host
    return out


@dataclasses.dataclass
class Incident:
    """One durable fault, job-scoped or fleet-scoped."""

    incident_id: str
    scope: str                    # "job" | "fleet"
    job_id: str                   # "" for fleet scope
    stage: str
    ranks: tuple[int, ...]        # sorted rank-set (job scope; () fleet)
    host: str                     # common-cause node name; "" undeclared
    state: str
    opened_tick: int
    last_seen_tick: int
    #: attribution tier of `host` — "host" | "switch" | "pod" (see
    #: `topology.TIERS`).  Job-scoped incidents are always host-tier;
    #: a fleet incident carries the NARROWEST tier that explains its
    #: co-activation set.
    tier: str = "host"
    onset_step: int = -1          # job-global onset from the first entry
    last_window_index: int = -1
    windows_seen: int = 0
    exposure_s: float = 0.0       # accumulated recoverable seconds
    recoverable_s: float = 0.0    # latest per-window estimate
    regime: str = ""
    persistence: float = 1.0
    resolve_reason: str = ""
    merged_into: str = ""         # job scope: owning fleet incident id
    members: tuple[str, ...] = () # fleet scope: member incident ids
    member_jobs: tuple[str, ...] = ()  # fleet scope: member job ids
    escalations: int = 0
    last_escalated_tick: int = -(10 ** 9)

    @property
    def live(self) -> bool:
        return self.state in LIVE_STATES

    def score(self, floor: float = 0.05) -> float:
        """Escalation score: accumulated exposure x persistence (floored,
        mirroring the fleet routing weight)."""
        return self.exposure_s * (floor + (1.0 - floor) * self.persistence)

    def as_row(self) -> dict:
        """Flat summary row for consoles / serving output."""
        return {
            "id": self.incident_id,
            "scope": self.scope,
            "job": self.job_id,
            "stage": self.stage,
            "ranks": list(self.ranks),
            "host": self.host,
            "tier": self.tier,
            "state": self.state,
            "exposure_s": round(self.exposure_s, 4),
            "regime": self.regime,
            "persistence": round(self.persistence, 3),
            "onset_step": self.onset_step,
            "opened_tick": self.opened_tick,
            "windows": self.windows_seen,
            "escalations": self.escalations,
            "resolve_reason": self.resolve_reason,
            "member_jobs": list(self.member_jobs),
        }


class IncidentEngine:
    """Durable cross-window, cross-job fault tracker.

    Feed it once per fleet tick (`observe`) with the tick's route
    entries, the evicted job ids, and (optionally) per-job activity
    series for common-cause correlation.  All state is bounded: live
    incidents are bounded by the fleet's candidate count, resolved
    history by `params.retention`.
    """

    def __init__(
        self,
        *,
        topology: Topology | None = None,
        params: IncidentParams | None = None,
        use_kernel: bool = False,
    ):
        self.topology = topology if topology is not None else Topology()
        self.params = params or IncidentParams()
        #: co-activation route: the NumPy ref per tick by default (the
        #: per-tick tensors are tiny); True dispatches the batched
        #: Pallas kernel instead (bit-identical — integer statistics).
        self.use_kernel = use_kernel
        self._job_incidents: dict[tuple[str, str], list[Incident]] = {}
        self._fleet_incidents: dict[tuple[str, str], Incident] = {}
        self._resolved: list[Incident] = []
        self.opened_total = 0
        self.merged_total = 0
        self.resolved_total = 0

    # -- reads -------------------------------------------------------------

    def incidents(self, *, live_only: bool = True) -> list[Incident]:
        """All incidents: fleet scope first, wider fabric tiers before
        narrower (pod > switch > host — `TIER_RANK`), then score, then
        id — the same total order `EscalationController` ranks by."""
        out = [i for i in self._iter_live()]
        if not live_only:
            out.extend(self._resolved)
        out.sort(
            key=lambda i: (
                i.scope != "fleet",
                -TIER_RANK.get(i.tier, 0),
                -i.score(self.params.persistence_floor),
                i.incident_id,
            )
        )
        return out

    def get(self, incident_id: str) -> Incident | None:
        for inc in self._iter_live():
            if inc.incident_id == incident_id:
                return inc
        for inc in self._resolved:
            if inc.incident_id == incident_id:
                return inc
        return None

    def counts(self) -> dict[str, int]:
        """Live incidents per state (+ lifetime resolved, + lifetime
        topology re-homings — the conflicting-claims counter)."""
        out = {OPEN: 0, ACTIVE: 0, MERGED: 0, COOLING: 0, RESOLVED: 0}
        for inc in self._iter_live():
            out[inc.state] += 1
        out[RESOLVED] = self.resolved_total
        out["rehomed"] = self.topology.rehomed
        return out

    def table(self, *, live_only: bool = True) -> list[dict]:
        return [i.as_row() for i in self.incidents(live_only=live_only)]

    def _iter_live(self) -> Iterable[Incident]:
        for incs in self._job_incidents.values():
            yield from incs
        yield from self._fleet_incidents.values()

    # -- the per-tick fold -------------------------------------------------

    def observe(
        self,
        tick: int,
        entries: Sequence[Any],
        *,
        evicted: Sequence[str] = (),
        activity: Mapping[str, tuple[np.ndarray, tuple[str, ...]]]
        | None = None,
        folded: Sequence[tuple[CorrelationGroup, np.ndarray]] | None = None,
    ) -> list[Incident]:
        """Fold one fleet tick; returns the live incidents (sorted).

        `entries` are route-entry-shaped records (``job_id``, ``stage``,
        ``rank``, ``recoverable_s``, ``regime``, ``persistence``,
        ``onset_step``, ``window_index`` — `fleet.service.RouteEntry`
        satisfies this); `activity` maps job_id to its
        ``(act[N, R, S] bool, stage names)`` thresholded activity series
        (see `core.streaming.StreamingRegimes.activity`), the substrate
        of cross-job correlation.

        `folded` is the sharded-coordinator alternative to `activity`:
        pre-reduced ``(CorrelationGroup, act[J, N, H_cand, S])`` pairs
        (shard partials from `fold_host_activity`, stacked in
        ``group.job_ids`` order) — the engine scores them directly
        instead of folding rank-level series itself.  Passing both is an
        error: one tick has exactly one correlation substrate.
        """
        if activity and folded:
            raise ValueError(
                "pass either per-job `activity` or pre-reduced `folded` "
                "partials, not both"
            )
        for job_id in sorted(set(evicted)):
            self._resolve_job(job_id, tick, reason="evicted")
            self.topology.forget(job_id)
        # deterministic fold order: a TOTAL key over every field the
        # fold reads, so any permutation of this tick's submissions —
        # including duplicate candidates differing only in window or
        # price — yields the identical incident set and ids.
        for e in sorted(
            entries,
            key=lambda e: (
                e.job_id,
                e.stage,
                e.rank,
                e.window_index,
                e.recoverable_s,
                e.persistence,
                e.onset_step,
                e.regime,
            ),
        ):
            self._fold_entry(tick, e)
        self._sweep(tick)
        if activity:
            self._correlate(tick, activity)
        elif folded:
            self.correlate_folded(tick, folded)
        self._refresh_fleet(tick)
        self._prune()
        return self.incidents()

    # -- single-job identity -----------------------------------------------

    def _fold_entry(self, tick: int, e: Any) -> None:
        if e.recoverable_s <= self.params.min_recoverable_s:
            return
        key = (e.job_id, e.stage)
        incs = self._job_incidents.setdefault(key, [])
        inc = self._match(incs, e)
        if inc is None:
            inc = Incident(
                incident_id=(
                    f"ij:{e.job_id}:{e.stage}:r{max(e.rank, -1)}:t{tick}"
                ),
                scope="job",
                job_id=e.job_id,
                stage=e.stage,
                ranks=(e.rank,) if e.rank >= 0 else (),
                host=self.topology.host_of(e.job_id, e.rank),
                state=OPEN,
                opened_tick=tick,
                last_seen_tick=tick,
            )
            incs.append(inc)
            self.opened_total += 1
        else:
            if e.rank >= 0 and e.rank not in inc.ranks:
                inc.ranks = tuple(sorted((*inc.ranks, e.rank)))
            if inc.state in (OPEN, COOLING) and tick > inc.last_seen_tick:
                # re-surfaced in a later tick: confirmed identity (a
                # cooling incident flaps back instead of duplicating)
                inc.state = ACTIVE
            inc.last_seen_tick = tick
        if not inc.host and e.rank >= 0:
            inc.host = self.topology.host_of(e.job_id, e.rank)
        # exposure accumulates once per window, MONOTONICALLY — the same
        # window re-routed on later ticks never double-counts, and
        # neither does a transport re-delivering an older window after a
        # newer one.  Entries that cannot declare a window coordinate
        # (window_index < 0, pre-whatif emitters) count exactly once.
        new_window = (
            e.window_index > inc.last_window_index
            if e.window_index >= 0
            else inc.windows_seen == 0
        )
        if new_window:
            inc.exposure_s += e.recoverable_s
            inc.windows_seen += 1
            inc.last_window_index = max(
                inc.last_window_index, e.window_index
            )
            if inc.windows_seen >= 2 and inc.state == OPEN:
                inc.state = ACTIVE
        inc.recoverable_s = e.recoverable_s
        inc.regime = e.regime
        inc.persistence = e.persistence
        if inc.onset_step < 0 and e.onset_step >= 0:
            inc.onset_step = e.onset_step

    def _match(self, incs: list[Incident], e: Any) -> Incident | None:
        """Window-to-window identity: exact rank membership first, then
        same-host siblings (two ranks of one host are one fault)."""
        live = [i for i in incs if i.live]
        for inc in live:
            if e.rank in inc.ranks:
                return inc
        host = self.topology.host_of(e.job_id, e.rank)
        if host:
            for inc in live:
                if inc.host == host:
                    return inc
        return None

    # -- lifecycle sweep ---------------------------------------------------

    def _sweep(self, tick: int) -> None:
        p = self.params
        for incs in self._job_incidents.values():
            for inc in incs:
                if not inc.live:
                    continue
                unseen = tick - inc.last_seen_tick
                if inc.state in (OPEN, ACTIVE, MERGED):
                    if unseen >= p.cooling_after:
                        inc.state = COOLING
                        if inc.merged_into:
                            inc.merged_into = ""
                elif inc.state == COOLING:
                    if unseen >= p.cooling_after + p.resolve_after:
                        self._resolve(inc, tick, reason="healed")

    def _resolve(self, inc: Incident, tick: int, *, reason: str) -> None:
        inc.state = RESOLVED
        inc.resolve_reason = reason
        inc.merged_into = ""
        self.resolved_total += 1
        self._resolved.append(inc)

    def _resolve_job(self, job_id: str, tick: int, *, reason: str) -> None:
        """A job left the fleet: every live incident of it resolves NOW —
        an evicted job's incident must never linger as live."""
        for (jid, _), incs in self._job_incidents.items():
            if jid != job_id:
                continue
            for inc in incs:
                if inc.live:
                    self._resolve(inc, tick, reason=reason)

    # -- cross-job common cause --------------------------------------------

    def correlation_plan(
        self, meta: Mapping[str, tuple[int, tuple[str, ...]]]
    ) -> list[CorrelationGroup]:
        """Derive the tick's correlation groups from fleet-wide activity
        METADATA (job id -> (step depth, stage vocabulary) — see
        `activity_meta`); no activity tensors are touched.

        Jobs group by stage vocabulary; within a group they align on
        their most recent COMMON history (regime rings may hold
        different depths — a job that joined the fleet a window late
        must still co-activate with its host peers), and the dense host
        axis holds only the hosts that >= min_jobs of the group's jobs
        can touch — the only promotable ones, so per-tick cost scales
        with *shared* hosts, never the fleet's full host count.  Groups
        that cannot promote (too few members, no shared host) are
        dropped here, before any activity is folded or shipped.

        This is the coordinator half of the cross-shard reduce: the
        plan is computed once from merged metadata, every shard folds
        its jobs' activity against it (`fold_host_activity`), and the
        stacked partials go through `correlate_folded`.
        """
        p = self.params
        if not len(self.topology):
            return []
        groups: dict[tuple[str, ...], list[str]] = {}
        depth: dict[str, int] = {}
        for job_id in sorted(meta):
            if job_id not in self.topology:
                continue
            n_steps, stages = meta[job_id]
            if n_steps <= 0:
                continue
            groups.setdefault(tuple(stages), []).append(job_id)
            depth[job_id] = int(n_steps)
        out: list[CorrelationGroup] = []
        for stages, members in sorted(groups.items()):
            if len(members) < p.min_jobs:
                continue
            # per-tier membership counts: how many member jobs touch
            # each host / switch / pod (a job counts once per node).
            counts: dict[str, dict[str, int]] = {t: {} for t in TIERS}
            touched: set[str] = set()
            for job_id in members:
                job_hosts = set(self.topology.hosts_for(job_id))
                touched |= job_hosts
                for tier in TIERS:
                    for node in {
                        n
                        for h in job_hosts
                        if (n := self.topology.node_of(tier, h))
                    }:
                        counts[tier][node] = counts[tier].get(node, 0) + 1
            cand_sw = sorted(
                n for n, c in counts["switch"].items() if c >= p.min_jobs
            )
            cand_pod = sorted(
                n for n, c in counts["pod"].items() if c >= p.min_jobs
            )
            # candidate hosts: touched hosts that sit under ANY
            # candidate node — shared directly, or privately held but
            # under a shared switch/pod (those must fold in so the
            # wider tier can see their activity).
            sw_set, pod_set = set(cand_sw), set(cand_pod)
            cand_hosts = sorted(
                h
                for h in touched
                if counts["host"].get(h, 0) >= p.min_jobs
                or self.topology.switch_of(h) in sw_set
                or self.topology.pod_of(h) in pod_set
            )
            if not cand_hosts:
                continue
            sw_col = {n: i for i, n in enumerate(cand_sw)}
            pod_col = {n: i for i, n in enumerate(cand_pod)}
            out.append(
                CorrelationGroup(
                    stages=stages,
                    job_ids=tuple(members),
                    n_steps=min(depth[j] for j in members),
                    hosts=tuple(cand_hosts),
                    switches=tuple(cand_sw),
                    switch_of=tuple(
                        sw_col.get(self.topology.switch_of(h), -1)
                        for h in cand_hosts
                    ),
                    pods=tuple(cand_pod),
                    pod_of=tuple(
                        pod_col.get(self.topology.pod_of(h), -1)
                        for h in cand_hosts
                    ),
                )
            )
        return out

    def correlate_folded(
        self,
        tick: int,
        folded: Sequence[tuple[CorrelationGroup, np.ndarray]],
    ) -> None:
        """Score pre-reduced host-folded activity and promote matches.

        `folded` pairs each `CorrelationGroup` of the tick's plan with
        its stacked partials ``act[J, N, H_cand, S]`` (J in
        ``group.job_ids`` order — across shards, the coordinator
        reassembles that order before calling).  This is the ONE scoring
        path: the single-process `activity` route reduces to it, so a
        sharded fleet's promotion decisions are bit-identical."""
        p = self.params
        for group, act in folded:
            act = np.asarray(act)
            if act.shape[0] == 0:
                continue
            tiers = group.tier_axes()
            stats = self._co_activation(act, tiers)
            # narrowest tier first: host candidates claim their member
            # incidents, then switch candidates gather only
            # still-unclaimed members, then pod — three faulted hosts
            # under one switch become ONE switch incident; a genuinely
            # shared host never re-appears as a duplicate switch view.
            claimed: set[str] = set()
            node_axis = {"switch": group.switches, "pod": group.pods}
            scored = [(stats[0], "host", group.hosts)] + [
                (pkt, axes.tier, node_axis[axes.tier])
                for pkt, axes in zip(stats[1:], tiers)
            ]
            for pkt, tier, nodes in scored:
                jobs = np.asarray(pkt.jobs)        # [S, nodes]
                coact = np.asarray(pkt.coact)      # [S, nodes]
                cand = np.argwhere(
                    (jobs >= p.min_jobs) & (coact >= p.min_coactive_steps)
                )
                for si, ni in cand:
                    self._promote(
                        tick,
                        group.stages[si],
                        nodes[ni],
                        tier=tier,
                        claimed=claimed,
                    )

    def _correlate(
        self,
        tick: int,
        activity: Mapping[str, tuple[np.ndarray, tuple[str, ...]]],
    ) -> None:
        """Single-process correlation: plan -> fold -> score, over one
        local partial set (the same pipeline a sharded coordinator runs
        distributed — see `CorrelationGroup`)."""
        plan = self.correlation_plan(activity_meta(activity))
        folded = []
        for group in plan:
            parts = fold_host_activity(group, activity, self.topology)
            folded.append(
                (group, np.stack([parts[j] for j in group.job_ids]))
            )
        self.correlate_folded(tick, folded)

    def _co_activation(self, act: np.ndarray, tiers: Sequence[Any] = ()):
        """Per-tier co-activation packets, host tier first (exact
        integer statistics on both routes — kernel == ref, bit-for-bit)."""
        if self.use_kernel:
            from ..kernels.frontier import tiered_co_activation

            return tiered_co_activation(act, tiers)
        from ..kernels.frontier import tiered_co_activation_ref

        return tiered_co_activation_ref(act, tiers)

    def _promote(
        self,
        tick: int,
        stage: str,
        node: str,
        *,
        tier: str = "host",
        claimed: set[str] | None = None,
    ) -> None:
        """Merge the live single-job incidents under (`tier`, `node`,
        `stage`) into one fleet-level incident (>= min_jobs distinct
        jobs required).

        `claimed` is the narrowest-tier guard: member ids a narrower
        tier already merged this tick are skipped, and on success this
        candidate's members are added — so a switch candidate only
        forms from hosts no host candidate explained, and a pod only
        from what no switch explained.  A candidate whose unclaimed
        members fall below quorum simply never opens."""
        members: list[Incident] = []
        for (job_id, inc_stage), incs in sorted(
            self._job_incidents.items()
        ):
            if inc_stage != stage:
                continue
            under = set(self.topology.ranks_under(tier, job_id, node))
            for inc in incs:
                if not inc.live:
                    continue
                if claimed is not None and inc.incident_id in claimed:
                    continue
                if set(inc.ranks) & under or (
                    inc.host
                    and self.topology.node_of(tier, inc.host) == node
                ):
                    members.append(inc)
        if len({m.job_id for m in members}) < self.params.min_jobs:
            return
        key = (tier, node, stage)
        fleet = self._fleet_incidents.get(key)
        if fleet is None or not fleet.live:
            prefix = "if" if tier == "host" else f"if:{tier}"
            fleet = Incident(
                incident_id=f"{prefix}:{node}:{stage}:t{tick}",
                scope="fleet",
                job_id="",
                stage=stage,
                ranks=(),
                host=node,
                state=OPEN,
                opened_tick=tick,
                last_seen_tick=tick,
                tier=tier,
            )
            self._fleet_incidents[key] = fleet
            self.merged_total += 1
        for m in members:
            if m.merged_into != fleet.incident_id:
                m.merged_into = fleet.incident_id
            m.state = MERGED
        if claimed is not None:
            claimed.update(m.incident_id for m in members)
        fleet.members = tuple(sorted(m.incident_id for m in members))
        fleet.member_jobs = tuple(sorted({m.job_id for m in members}))
        fleet.last_seen_tick = tick
        if fleet.state == COOLING or (
            fleet.state == OPEN and tick > fleet.opened_tick
        ):
            fleet.state = ACTIVE

    def _refresh_fleet(self, tick: int) -> None:
        """Derive each fleet incident from its members; demote on lost
        quorum, cool/resolve on silence, release members on resolve."""
        p = self.params
        for key, fleet in sorted(self._fleet_incidents.items()):
            if not fleet.live:
                continue
            members = [
                inc
                for inc in self._iter_live()
                if inc.scope == "job"
                and inc.merged_into == fleet.incident_id
                and inc.state == MERGED
            ]
            if members:
                fleet.members = tuple(
                    sorted(m.incident_id for m in members)
                )
                fleet.member_jobs = tuple(
                    sorted({m.job_id for m in members})
                )
                fleet.exposure_s = sum(m.exposure_s for m in members)
                fleet.recoverable_s = sum(m.recoverable_s for m in members)
                fleet.persistence = max(m.persistence for m in members)
                best = max(members, key=lambda m: m.exposure_s)
                fleet.regime = best.regime
                onsets = [m.onset_step for m in members if m.onset_step >= 0]
                fleet.onset_step = min(onsets) if onsets else -1
            quorum = len({m.job_id for m in members}) >= p.min_jobs
            unseen = tick - fleet.last_seen_tick
            if not quorum and fleet.state in (OPEN, ACTIVE):
                # lost its members (healed / evicted / cooled): the
                # common cause is gone — release survivors to their own
                # lifecycle and resolve the fleet view.
                for m in members:
                    m.state = ACTIVE
                    m.merged_into = ""
                self._resolve(fleet, tick, reason="members_resolved")
            elif fleet.state in (OPEN, ACTIVE) and unseen >= p.cooling_after:
                fleet.state = COOLING
            elif (
                fleet.state == COOLING
                and unseen >= p.cooling_after + p.resolve_after
            ):
                for m in members:
                    m.state = ACTIVE
                    m.merged_into = ""
                self._resolve(fleet, tick, reason="healed")

    # -- bounded history ---------------------------------------------------

    def _prune(self) -> None:
        keep = self.params.retention
        if len(self._resolved) > keep:
            del self._resolved[: len(self._resolved) - keep]
        # resolved incidents leave the live maps entirely
        for key in [
            k
            for k, incs in self._job_incidents.items()
            if not any(i.live for i in incs)
        ]:
            del self._job_incidents[key]
        for key, incs in self._job_incidents.items():
            incs[:] = [i for i in incs if i.live]
        for key in [
            k for k, f in self._fleet_incidents.items() if not f.live
        ]:
            del self._fleet_incidents[key]
