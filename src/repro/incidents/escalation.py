"""Budgeted profiler escalation: the paper's routing claim, operational.

The routing answer says where a heavy profiler is worth aiming; this
controller decides *which of those attachments actually happen*, under a
hard per-tick budget.  Heavy profilers are expensive (they perturb the
very jobs being diagnosed), so production escalation is budgeted and
hysteretic — a flapping incident must not drain the budget that a
steady, expensive one needs.

Mechanics (all deterministic):

  * a **token bucket** refills `budget_per_tick` tokens per fleet tick
    up to `bucket_cap` (unused budget carries over, bounded), and each
    emitted action consumes one token;
  * emissions per tick are additionally HARD-capped at
    `budget_per_tick` — the bucket smooths bursts, it never licenses
    exceeding the per-tick budget (asserted in
    ``benchmarks/incident_engine.py``);
  * candidates are the live, un-merged incidents (fleet-scope
    common-cause incidents outrank every single-job incident), ranked
    by accumulated-recoverable x persistence, ties broken by incident
    id;
  * **hysteresis**: an incident escalated at tick T is ineligible until
    ``T + hysteresis_ticks``, and a cooling incident is never escalated
    — so open/cool flapping cannot re-consume tokens every flap.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .engine import ACTIVE, Incident, OPEN, TIER_RANK

__all__ = ["EscalationController", "ProfilerAction"]


@dataclasses.dataclass(frozen=True)
class ProfilerAction:
    """One 'attach a heavy profiler to (job, host, stage)' decision."""

    incident_id: str
    job_id: str                  # "" for fleet-scope incidents
    jobs: tuple[str, ...]        # member jobs (fleet) or (job_id,)
    host: str
    stage: str
    ranks: tuple[int, ...]
    tick: int
    score: float


class EscalationController:
    """Token-bucket escalation over an incident stream."""

    def __init__(
        self,
        *,
        budget_per_tick: int = 2,
        bucket_cap: int | None = None,
        hysteresis_ticks: int = 3,
        persistence_floor: float = 0.05,
    ):
        if budget_per_tick < 1:
            raise ValueError("budget_per_tick must be >= 1")
        self.budget_per_tick = budget_per_tick
        self.bucket_cap = (
            2 * budget_per_tick if bucket_cap is None else bucket_cap
        )
        if self.bucket_cap < budget_per_tick:
            raise ValueError("bucket_cap must be >= budget_per_tick")
        self.hysteresis_ticks = hysteresis_ticks
        self.persistence_floor = persistence_floor
        self._tokens = budget_per_tick   # first tick never exceeds budget
        self._last_tick: int | None = None
        self._emitted_this_tick = 0
        self.actions_total = 0

    @property
    def tokens(self) -> int:
        return self._tokens

    def plan(
        self, tick: int, incidents: Sequence[Incident]
    ) -> list[ProfilerAction]:
        """Emit this tick's profiler attachments (at most
        `budget_per_tick`, never more than the bucket holds) and mark
        the escalated incidents.

        Call once per fleet tick with the engine's live incidents; ticks
        may skip (the bucket refills per elapsed tick, capped).
        """
        if self._last_tick is not None and tick > self._last_tick:
            self._tokens = min(
                self.bucket_cap,
                self._tokens + (tick - self._last_tick) * self.budget_per_tick,
            )
        if tick != self._last_tick:
            # the per-tick HARD cap holds even if plan() is called more
            # than once for the same tick (carried-over tokens must not
            # leak past it through a second call)
            self._emitted_this_tick = 0
        self._last_tick = tick

        eligible = [
            inc
            for inc in incidents
            if inc.state in (OPEN, ACTIVE)
            and not inc.merged_into
            and inc.exposure_s > 0.0
            and tick - inc.last_escalated_tick >= self.hysteresis_ticks
        ]
        eligible.sort(
            key=lambda i: (
                i.scope != "fleet",                   # fleet outranks job
                -TIER_RANK.get(i.tier, 0),            # pod > switch > host
                -i.score(self.persistence_floor),
                i.incident_id,
            )
        )
        budget = min(
            self.budget_per_tick - self._emitted_this_tick, self._tokens
        )
        actions: list[ProfilerAction] = []
        for inc in eligible[: max(0, budget)]:
            jobs = (
                inc.member_jobs if inc.scope == "fleet" else (inc.job_id,)
            )
            actions.append(
                ProfilerAction(
                    incident_id=inc.incident_id,
                    job_id=inc.job_id,
                    jobs=jobs,
                    host=inc.host,
                    stage=inc.stage,
                    ranks=inc.ranks,
                    tick=tick,
                    score=inc.score(self.persistence_floor),
                )
            )
            inc.escalations += 1
            inc.last_escalated_tick = tick
        self._tokens -= len(actions)
        self._emitted_this_tick += len(actions)
        self.actions_total += len(actions)
        return actions
