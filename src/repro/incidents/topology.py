"""Fleet topology: the tiered rank -> host -> switch -> pod placement map
the incident tier joins on.

Per-job evidence is rank-indexed; physical faults are fabric-indexed —
and "When Scaling Fails" shows the fabric tiers above the host
(oversubscribed uplinks, flapping switches, pod-wide congestion)
dominate many production slowdowns.  The `Topology` therefore holds a
HIERARCHY, not a flat map:

    rank --(per-job placement)--> host --(fabric)--> switch --> pod

so the incident engine can (a) merge two rank-candidates of one job that
share a node into one rank-set incident, (b) correlate incidents ACROSS
jobs that share a node, and (c) promote each co-activation set to the
*narrowest tier that explains it* — three faulted hosts under one switch
are ONE switch incident, not three host incidents.

Placements arrive two ways, both landing here:

  * statically, from a `sim.ClusterSpec` / an operator-provided map
    (`Topology.from_jobs` with optional per-rank switch/pod tuples);
  * dynamically, from the wire: SFP2-v2 packets carry per-rank host
    ids and SFP2-v3 packets additionally carry per-rank switch/pod ids;
    `FleetService` declares each job's placement as packets arrive.

The fabric maps are fleet-global (a host has ONE switch, a switch ONE
pod, regardless of which job observed it) and *last-writer-wins*: a
conflicting claim — a rank re-homed to a different host mid-run, a host
re-cabled under a different switch — overwrites the previous placement
and increments the `rehomed` counter, which `FleetService.snapshot()`
surfaces so operators can see churn instead of silent drift.  Lower
tiers are derivable from upper ones: declaring `(host, switch, pod)`
also declares `(switch, pod)`; a host with no declared switch simply
cannot be switch- or pod-correlated (the engine keeps its evidence at
the host tier rather than guessing).

A job with no declared placement cannot be correlated at any tier — its
incidents stay job-scoped.
"""
from __future__ import annotations

from typing import Mapping, Sequence

__all__ = ["TIERS", "Topology"]

#: attribution tiers, narrowest first — the order the incident engine
#: promotes in (host evidence claims members before switch, switch
#: before pod).
TIERS = ("host", "switch", "pod")


class Topology:
    """Mutable tiered fleet placement map with deterministic indexing."""

    def __init__(self):
        self._jobs: dict[str, tuple[str, ...]] = {}
        #: fabric maps, fleet-global: host -> switch, switch -> pod.
        self._switch_of: dict[str, str] = {}
        self._pod_of: dict[str, str] = {}
        #: conflicting-claim counter (last-writer-wins re-homings): a
        #: rank moved to a different host, a host to a different switch,
        #: or a switch to a different pod.  Monotonic; surfaced in
        #: `FleetService.snapshot()["rehomed"]`.
        self.rehomed = 0

    @classmethod
    def from_jobs(
        cls,
        placements: Mapping[str, Sequence[str]],
        *,
        switches: Mapping[str, Sequence[str]] | None = None,
        pods: Mapping[str, Sequence[str]] | None = None,
    ) -> "Topology":
        """Build from `{job_id: per-rank host names}` (+ optional
        per-rank switch/pod names, aligned with the host tuples)."""
        t = cls()
        for job_id, hosts in placements.items():
            t.declare(
                job_id,
                hosts,
                switches=(switches or {}).get(job_id, ()),
                pods=(pods or {}).get(job_id, ()),
            )
        return t

    # -- writes ------------------------------------------------------------

    def declare(
        self,
        job_id: str,
        hosts: Sequence[str],
        *,
        switches: Sequence[str] = (),
        pods: Sequence[str] = (),
    ) -> None:
        """Declare (or replace) one job's per-rank placement.

        An empty `hosts` is a no-op: packets without the host section
        must never erase a previously declared placement.  Non-empty
        `switches` / `pods` must align with `hosts` per rank; they feed
        the fleet-global fabric maps (`declare_fabric` per host).
        Conflicting re-declarations win (last writer) and count into
        `rehomed` — one count per rank whose host actually changed.
        """
        hosts = tuple(str(h) for h in hosts)
        if not hosts:
            return
        switches = tuple(str(s) for s in switches)
        pods = tuple(str(p) for p in pods)
        if switches and len(switches) != len(hosts):
            raise ValueError(
                f"switches must align with hosts: {len(switches)} != "
                f"{len(hosts)}"
            )
        if pods and len(pods) != len(hosts):
            raise ValueError(
                f"pods must align with hosts: {len(pods)} != {len(hosts)}"
            )
        prev = self._jobs.get(job_id, ())
        self.rehomed += sum(
            1
            for r in range(min(len(prev), len(hosts)))
            if prev[r] != hosts[r]
        )
        self._jobs[job_id] = hosts
        for r, h in enumerate(hosts):
            self.declare_fabric(
                h,
                switch=switches[r] if switches else "",
                pod=pods[r] if pods else "",
            )

    def declare_fabric(
        self, host: str, *, switch: str = "", pod: str = ""
    ) -> None:
        """Declare one host's fabric placement (host -> switch -> pod).

        Empty tiers are no-ops (a v2 packet never erases a v3 claim);
        a pod claim requires a switch to hang it from.  Conflicting
        claims are last-writer-wins and counted into `rehomed`.
        """
        switch, pod = str(switch), str(pod)
        if switch:
            prev = self._switch_of.get(host, "")
            if prev and prev != switch:
                self.rehomed += 1
            self._switch_of[host] = switch
            if pod:
                prev = self._pod_of.get(switch, "")
                if prev and prev != pod:
                    self.rehomed += 1
                self._pod_of[switch] = pod
        elif pod:
            raise ValueError(
                f"pod {pod!r} declared for host {host!r} without a switch"
            )

    def forget(self, job_id: str) -> None:
        """Drop a job's placement (eviction path — bounded state).

        Fabric maps persist: the cabling outlives any one job, and the
        engine only reaches fabric nodes through live jobs' hosts."""
        self._jobs.pop(job_id, None)

    # -- reads (host tier, the PR-8 surface) -------------------------------

    def host_of(self, job_id: str, rank: int) -> str:
        """Host of one rank ("" when the job or rank is undeclared)."""
        hosts = self._jobs.get(job_id, ())
        return hosts[rank] if 0 <= rank < len(hosts) else ""

    def hosts_for(self, job_id: str) -> tuple[str, ...]:
        return self._jobs.get(job_id, ())

    def jobs(self) -> tuple[str, ...]:
        """Declared job ids, sorted (deterministic iteration order)."""
        return tuple(sorted(self._jobs))

    def hosts(self) -> tuple[str, ...]:
        """Every distinct host name, sorted — the canonical host axis."""
        seen: set[str] = set()
        for hs in self._jobs.values():
            seen.update(hs)
        return tuple(sorted(seen))

    def host_index(self) -> dict[str, int]:
        """host name -> dense index over `hosts()` (the kernel's H axis)."""
        return {h: i for i, h in enumerate(self.hosts())}

    def jobs_on(self, host: str) -> tuple[str, ...]:
        """Jobs with at least one rank on `host`, sorted."""
        return tuple(
            sorted(j for j, hs in self._jobs.items() if host in hs)
        )

    def ranks_on(self, job_id: str, host: str) -> tuple[int, ...]:
        """Ranks of `job_id` served by `host`."""
        return tuple(
            r
            for r, h in enumerate(self._jobs.get(job_id, ()))
            if h == host
        )

    # -- reads (fabric tiers) ----------------------------------------------

    def switch_of(self, host: str) -> str:
        """Declared switch above `host` ("" = fabric undeclared)."""
        return self._switch_of.get(host, "")

    def pod_of_switch(self, switch: str) -> str:
        """Declared pod above `switch` ("" = undeclared)."""
        return self._pod_of.get(switch, "")

    def pod_of(self, host: str) -> str:
        """Declared pod above `host` (via its switch; "" = undeclared)."""
        return self._pod_of.get(self._switch_of.get(host, ""), "")

    def node_of(self, tier: str, host: str) -> str:
        """`host`'s enclosing node at `tier` — the host itself, its
        switch, or its pod ("" when that tier is undeclared)."""
        if tier == "host":
            return host
        if tier == "switch":
            return self.switch_of(host)
        if tier == "pod":
            return self.pod_of(host)
        raise ValueError(f"unknown tier {tier!r}")

    def tier_of(self, tier: str, job_id: str, rank: int) -> str:
        """One rank's enclosing node at `tier` ("" when undeclared)."""
        return self.node_of(tier, self.host_of(job_id, rank))

    def nodes(self, tier: str) -> tuple[str, ...]:
        """Every distinct node name at `tier`, sorted — the canonical
        axis of that tier.  Only nodes reachable from a declared job's
        hosts count (stale fabric entries never widen a kernel axis)."""
        return tuple(
            sorted(
                {
                    n
                    for h in self.hosts()
                    if (n := self.node_of(tier, h))
                }
            )
        )

    def hosts_under(self, tier: str, node: str) -> tuple[str, ...]:
        """Declared-job hosts whose `tier` node is `node`, sorted."""
        return tuple(
            h for h in self.hosts() if self.node_of(tier, h) == node
        )

    def jobs_under(self, tier: str, node: str) -> tuple[str, ...]:
        """Jobs with >= 1 rank under `node` at `tier`, sorted."""
        return tuple(
            sorted(
                j
                for j, hs in self._jobs.items()
                if any(self.node_of(tier, h) == node for h in hs)
            )
        )

    def ranks_under(self, tier: str, job_id: str, node: str) -> tuple[int, ...]:
        """Ranks of `job_id` whose `tier` node is `node`."""
        return tuple(
            r
            for r, h in enumerate(self._jobs.get(job_id, ()))
            if self.node_of(tier, h) == node
        )

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs

    def __len__(self) -> int:
        return len(self._jobs)
