"""Fleet topology: the (job, rank) -> host map the incident tier joins on.

Per-job evidence is rank-indexed; physical faults are host-indexed.  The
`Topology` holds the declared placement of every job's ranks so the
incident engine can (a) merge two rank-candidates of one job that share
a host into one rank-set incident, and (b) correlate incidents ACROSS
jobs that share a host — the common-cause promotion.

Placements arrive two ways, both landing here:

  * statically, from a `sim.ClusterSpec` / an operator-provided map
    (`Topology.from_jobs`);
  * dynamically, from the wire: SFP2-v2 evidence packets carry an
    optional per-rank host-id section, and `FleetService` declares each
    job's placement as its packets arrive.

A job with no declared placement simply cannot be host-correlated — the
engine keeps its incidents job-scoped rather than guessing.
"""
from __future__ import annotations

from typing import Iterable, Mapping, Sequence

__all__ = ["Topology"]


class Topology:
    """Mutable fleet placement map with deterministic host indexing."""

    def __init__(self):
        self._jobs: dict[str, tuple[str, ...]] = {}

    @classmethod
    def from_jobs(
        cls, placements: Mapping[str, Sequence[str]]
    ) -> "Topology":
        """Build from `{job_id: per-rank host names}`."""
        t = cls()
        for job_id, hosts in placements.items():
            t.declare(job_id, hosts)
        return t

    # -- writes ------------------------------------------------------------

    def declare(self, job_id: str, hosts: Sequence[str]) -> None:
        """Declare (or replace) one job's per-rank host names.

        An empty `hosts` is a no-op: packets without the host section
        must never erase a previously declared placement.
        """
        hosts = tuple(str(h) for h in hosts)
        if hosts:
            self._jobs[job_id] = hosts

    def forget(self, job_id: str) -> None:
        """Drop a job's placement (eviction path — bounded state)."""
        self._jobs.pop(job_id, None)

    # -- reads -------------------------------------------------------------

    def host_of(self, job_id: str, rank: int) -> str:
        """Host of one rank ("" when the job or rank is undeclared)."""
        hosts = self._jobs.get(job_id, ())
        return hosts[rank] if 0 <= rank < len(hosts) else ""

    def hosts_for(self, job_id: str) -> tuple[str, ...]:
        return self._jobs.get(job_id, ())

    def jobs(self) -> tuple[str, ...]:
        """Declared job ids, sorted (deterministic iteration order)."""
        return tuple(sorted(self._jobs))

    def hosts(self) -> tuple[str, ...]:
        """Every distinct host name, sorted — the canonical host axis."""
        seen: set[str] = set()
        for hs in self._jobs.values():
            seen.update(hs)
        return tuple(sorted(seen))

    def host_index(self) -> dict[str, int]:
        """host name -> dense index over `hosts()` (the kernel's H axis)."""
        return {h: i for i, h in enumerate(self.hosts())}

    def jobs_on(self, host: str) -> tuple[str, ...]:
        """Jobs with at least one rank on `host`, sorted."""
        return tuple(
            sorted(j for j, hs in self._jobs.items() if host in hs)
        )

    def ranks_on(self, job_id: str, host: str) -> tuple[int, ...]:
        """Ranks of `job_id` served by `host`."""
        return tuple(
            r
            for r, h in enumerate(self._jobs.get(job_id, ()))
            if h == host
        )

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs

    def __len__(self) -> int:
        return len(self._jobs)
