"""repro.incidents — persistent cross-job fault tracking (the incident tier).

The fleet service re-derives "where to aim the profiler" from scratch
every window; this tier gives that answer *identity, lifecycle, and a
budget*.  Route entries become durable `Incident` objects
(open -> active -> merged -> cooling -> resolved), the same fault
re-surfacing across windows dedups onto one incident, faults appearing
in >= 2 jobs on one host promote to a fleet-level common-cause incident
(`Topology` join + the batched co-activation kernel), and a token-bucket
`EscalationController` turns the ranked incidents into at most B
profiler attachments per tick, with hysteresis.

Layers:
  topology    the tiered rank -> host -> switch -> pod placement map
              (static, or learned from SFP2-v2/v3 packets' placement
              sections)
  engine      incident identity, lifecycle, exposure accumulation,
              cross-job promotion to the narrowest explaining tier
  escalation  budgeted, hysteretic profiler-attachment planning (fleet
              before job, wider tier before narrower)
"""
from .engine import (
    ACTIVE,
    COOLING,
    CorrelationGroup,
    Incident,
    IncidentEngine,
    IncidentParams,
    LIVE_STATES,
    MERGED,
    OPEN,
    RESOLVED,
    TIER_RANK,
    activity_meta,
    fold_host_activity,
)
from .escalation import EscalationController, ProfilerAction
from .topology import TIERS, Topology

__all__ = [
    "ACTIVE",
    "COOLING",
    "CorrelationGroup",
    "EscalationController",
    "Incident",
    "IncidentEngine",
    "IncidentParams",
    "LIVE_STATES",
    "MERGED",
    "OPEN",
    "ProfilerAction",
    "RESOLVED",
    "TIERS",
    "TIER_RANK",
    "Topology",
    "activity_meta",
    "fold_host_activity",
]
